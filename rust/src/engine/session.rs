//! Session layer: load once, query many times, mutate in place.
//!
//! [`Session::load`] performs every input-only computation once — the
//! Section 6 degree-descending relabeling, the relabeled CSR (with its
//! undirected view and transpose), and the degree-mass-balanced
//! [`PartitionSet`] — and then serves repeated [`CountQuery`]s against the
//! cached state. This is what makes repeated queries cheap: the seed
//! coordinator rebuilt ordering, queue and counters on every call, so a
//! serving deployment paid full setup cost per request.
//!
//! Since the stream layer landed, a session is also *live*:
//! [`Session::maintain`] registers a (size, direction) counter,
//! [`Session::apply_edges`] applies a batch of edge insertions/deletions
//! by patching the delta overlay and re-enumerating only the instances
//! containing each changed edge, and [`Session::maintained_counts`] reads
//! the incrementally maintained per-vertex counts back. Full counts keep
//! working while deltas are pending: the enumerators run over the overlay
//! view (same code path, see [`crate::graph::GraphProbe`]) with a freshly
//! budgeted partition, and once the overlay outgrows
//! `SessionConfig::compact_ratio` the CSR is rebuilt (counting-sort
//! bucket build) and the cached partitions refreshed.
//!
//! Every query picks its own motif size, direction, scheduler and sink;
//! the per-query state (scheduler queues, counter arrays) is rebuilt from
//! the cached partition in O(items + n·classes), with no graph passes.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::metrics::{RunReport, WorkerMetrics};
use crate::graph::csr::Graph;
use crate::graph::ordering::VertexOrdering;
use crate::graph::{AdjacencyMode, GraphProbe};
use crate::motifs::counter::{CounterMode, MotifCounts, SlotMapper};
use crate::motifs::iso::NO_SLOT;
use crate::motifs::{bfs3, bfs4, Direction, MotifSize};
use crate::stream::delta::{reenumerate_edge, EdgeChange, MaintainedCounts};
use crate::stream::overlay::{DeltaOverlay, OverlayView};
use crate::stream::{DeltaOp, DeltaReport, EdgeDelta};

use super::partition::PartitionSet;
use super::scheduler::{Scheduler, SchedulerMode, SharedCursorScheduler, WorkStealingScheduler};
use super::sink::{make_sink, CounterSink};

/// Load-time configuration (everything a query may NOT change, because the
/// cached partition depends on it).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Worker threads = shard count; 0 = one per available core.
    pub workers: usize,
    /// Relabel by descending degree before counting (paper Section 6).
    pub reorder: bool,
    /// Max (root, neighbor) units per work item.
    pub max_units_per_item: usize,
    /// Rebuild the CSR once the delta overlay's side-list occupancy
    /// exceeds this fraction of the base adjacency (checked per
    /// `apply_edges` batch). 0.0 compacts after every dirty batch.
    pub compact_ratio: f64,
    /// Adjacency tier the probes answer through: pure CSR, or CSR plus
    /// bitmap hub rows (the hybrid hot path). Rebuilt after compaction.
    pub adjacency: AdjacencyMode,
    /// Hub degree threshold for the hybrid tier; `None` picks
    /// [`crate::graph::Csr::default_hub_threshold`] (≈ √m).
    pub hub_threshold: Option<usize>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            workers: 0,
            reorder: true,
            max_units_per_item: 64,
            compact_ratio: 0.25,
            adjacency: AdjacencyMode::Hybrid,
            hub_threshold: None,
        }
    }
}

/// One counting request against a loaded session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CountQuery {
    pub size: MotifSize,
    pub direction: Direction,
    pub scheduler: SchedulerMode,
    pub sink: CounterMode,
}

impl Default for CountQuery {
    fn default() -> Self {
        CountQuery {
            size: MotifSize::Three,
            direction: Direction::Directed,
            scheduler: SchedulerMode::WorkStealing,
            sink: CounterMode::Sharded,
        }
    }
}

impl CountQuery {
    /// Validating builder — the one construction path shared by the CLI,
    /// the service wire codec and the benches, so the accepted knob names
    /// (`stealing-batch`, `partition`, ...) can't drift between surfaces.
    pub fn builder() -> CountQueryBuilder {
        CountQueryBuilder::default()
    }
}

/// Builder behind [`CountQuery::builder`]. Typed setters are infallible;
/// the `*_name` setters parse the CLI/wire spellings and defer their
/// error to [`CountQueryBuilder::build`], so call sites chain without
/// intermediate `?`s.
#[derive(Debug, Clone, Default)]
pub struct CountQueryBuilder {
    query: CountQuery,
    err: Option<String>,
}

impl CountQueryBuilder {
    pub fn size(mut self, size: MotifSize) -> Self {
        self.query.size = size;
        self
    }

    /// Motif size from its integer spelling (3 or 4).
    pub fn size_k(mut self, k: usize) -> Self {
        match MotifSize::from_k(k) {
            Some(s) => self.query.size = s,
            None => self.fail(format!("motif size must be 3 or 4, got {k}")),
        }
        self
    }

    pub fn direction(mut self, direction: Direction) -> Self {
        self.query.direction = direction;
        self
    }

    /// Direction from its wire spelling: `directed` | `undirected`.
    pub fn direction_name(mut self, name: &str) -> Self {
        match Direction::parse(name) {
            Some(d) => self.query.direction = d,
            None => self.fail(format!("unknown direction {name:?} (directed | undirected)")),
        }
        self
    }

    pub fn scheduler(mut self, scheduler: SchedulerMode) -> Self {
        self.query.scheduler = scheduler;
        self
    }

    /// Scheduler from its CLI spelling: `cursor` | `stealing` |
    /// `stealing-batch`.
    pub fn scheduler_name(mut self, name: &str) -> Self {
        match name {
            "cursor" => self.query.scheduler = SchedulerMode::SharedCursor,
            "stealing" => self.query.scheduler = SchedulerMode::WorkStealing,
            "stealing-batch" => self.query.scheduler = SchedulerMode::WorkStealingBatch,
            _ => self.fail(format!(
                "unknown scheduler {name:?} (cursor | stealing | stealing-batch)"
            )),
        }
        self
    }

    pub fn sink(mut self, sink: CounterMode) -> Self {
        self.query.sink = sink;
        self
    }

    /// Counter sink from its CLI spelling: `atomic` | `sharded` |
    /// `partition`.
    pub fn sink_name(mut self, name: &str) -> Self {
        match name {
            "atomic" => self.query.sink = CounterMode::Atomic,
            "sharded" => self.query.sink = CounterMode::Sharded,
            "partition" => self.query.sink = CounterMode::PartitionLocal,
            _ => self.fail(format!("unknown sink {name:?} (atomic | sharded | partition)")),
        }
        self
    }

    fn fail(&mut self, msg: String) {
        // first error wins: it names the knob the caller got wrong
        if self.err.is_none() {
            self.err = Some(msg);
        }
    }

    pub fn build(self) -> Result<CountQuery> {
        match self.err {
            Some(msg) => bail!("{msg}"),
            None => Ok(self.query),
        }
    }
}

/// A graph loaded for repeated motif counting and live edge updates:
/// cached ordering, relabeled CSR, partition set, delta overlay and
/// incrementally maintained counters.
pub struct Session {
    directed: bool,
    n: usize,
    ordering: VertexOrdering,
    /// Relabeled base graph (processing ids); patched by `overlay`.
    h: Graph,
    partitions: PartitionSet,
    /// Pending edge patches over `h` (empty when no deltas applied since
    /// the last compaction).
    overlay: DeltaOverlay,
    /// Incrementally maintained per-vertex counters (processing ids).
    maintained: Vec<MaintainedCounts>,
    /// Requested worker count (pre-clamping), reused on compaction.
    workers: usize,
    max_units_per_item: usize,
    compact_ratio: f64,
    /// Adjacency tier; the hybrid bitmap rows are rebuilt on compaction.
    adjacency: AdjacencyMode,
    hub_threshold: Option<usize>,
    compactions: usize,
    setup_secs: f64,
    served: AtomicUsize,
    /// Pool identity: which graph this session serves. `None` for
    /// hand-built sessions outside a [`crate::service::SessionPool`].
    graph_id: Option<String>,
}

impl Session {
    /// Load with default configuration.
    pub fn load(graph: &Graph) -> Session {
        Session::load_with(graph, &SessionConfig::default())
    }

    /// Load: relabel, build the undirected/transpose views, partition.
    /// All of it happens exactly once per session.
    pub fn load_with(graph: &Graph, cfg: &SessionConfig) -> Session {
        let t0 = Instant::now();
        let n = graph.n();
        let ordering = if cfg.reorder {
            VertexOrdering::degree_descending(graph)
        } else {
            VertexOrdering::identity(n)
        };
        let mut h = ordering.apply(graph);
        if cfg.adjacency == AdjacencyMode::Hybrid {
            h.enable_hybrid(cfg.hub_threshold);
        }
        let workers = resolve_workers(cfg.workers);
        let max_units_per_item = cfg.max_units_per_item.max(1);
        let partitions = PartitionSet::build(&h, workers, max_units_per_item);
        Session {
            directed: graph.directed,
            n,
            ordering,
            h,
            partitions,
            overlay: DeltaOverlay::new(),
            maintained: Vec::new(),
            workers,
            max_units_per_item,
            compact_ratio: cfg.compact_ratio.max(0.0),
            adjacency: cfg.adjacency,
            hub_threshold: cfg.hub_threshold,
            compactions: 0,
            setup_secs: t0.elapsed().as_secs_f64(),
            served: AtomicUsize::new(0),
            graph_id: None,
        }
    }

    /// Tag this session with the graph id it serves (pool identity).
    pub fn set_graph_id(&mut self, id: impl Into<String>) {
        self.graph_id = Some(id.into());
    }

    /// The graph id this session serves, when pooled.
    pub fn graph_id(&self) -> Option<&str> {
        self.graph_id.as_deref()
    }

    /// Vertex count of the loaded graph.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Worker threads (= shard count) queries run with.
    pub fn workers(&self) -> usize {
        self.partitions.n_shards()
    }

    /// Wall-clock seconds the one-time setup took.
    pub fn setup_secs(&self) -> f64 {
        self.setup_secs
    }

    /// Queries served so far.
    pub fn queries_served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    pub fn partitions(&self) -> &PartitionSet {
        &self.partitions
    }

    /// Pending overlay side-list entries (0 when fully compacted).
    pub fn overlay_entries(&self) -> usize {
        self.overlay.entries()
    }

    /// Overlay occupancy relative to the base CSR.
    pub fn overlay_ratio(&self) -> f64 {
        self.overlay.ratio(&self.h)
    }

    /// CSR rebuilds performed by `apply_edges` so far.
    pub fn compactions(&self) -> usize {
        self.compactions
    }

    /// Adjacency tier this session's probes answer through.
    pub fn adjacency(&self) -> AdjacencyMode {
        self.adjacency
    }

    /// Bytes held by the hybrid bitmap tier (0 under [`AdjacencyMode::Csr`]).
    pub fn tier_memory_bytes(&self) -> usize {
        self.h.tier_memory_bytes()
    }

    /// Bitmap hub rows of the relabeled undirected view.
    pub fn hub_rows(&self) -> usize {
        self.h.hub_rows()
    }

    /// Total resident bytes of this session: the relabeled CSR views and
    /// hub-tier bitmaps, the pending delta overlay, the cached partition
    /// items, and every maintained per-vertex counter. This is the number
    /// the [`crate::service::SessionPool`] byte budget meters — it grows
    /// as deltas accumulate and counters are registered, and shrinks on
    /// compaction.
    pub fn memory_bytes(&self) -> usize {
        self.h.memory_bytes()
            + self.overlay.memory_bytes()
            + self.partitions.memory_bytes()
            + self.maintained.iter().map(|m| m.memory_bytes()).sum::<usize>()
            + self.ordering.memory_bytes()
    }

    /// The incrementally maintained counters.
    pub fn maintained(&self) -> &[MaintainedCounts] {
        &self.maintained
    }

    /// Count all k-motifs per vertex for one query.
    pub fn count(&self, query: &CountQuery) -> Result<MotifCounts> {
        Ok(self.count_with_report(query)?.0)
    }

    /// As [`Session::count`], also returning the run report. The report's
    /// `setup_secs`/`setup_reused` show whether this call paid for setup
    /// (first query) or served from cache. While deltas are pending the
    /// enumeration runs over the overlay view with a freshly budgeted
    /// partition (the cached one has stale unit counts).
    pub fn count_with_report(&self, query: &CountQuery) -> Result<(MotifCounts, RunReport)> {
        if query.direction == Direction::Directed && !self.directed {
            bail!("directed motif counting requested on an undirected graph");
        }
        let reused = self.served.fetch_add(1, Ordering::Relaxed) > 0;
        let start = Instant::now();
        let k = query.size.k();
        let mapper = SlotMapper::new(k, query.direction);
        let n_classes = mapper.n_classes();

        let (per_vertex_proc, instances, metrics, queue_items, queue_units) =
            if self.overlay.is_empty() {
                self.run_query(&self.h, &self.partitions, query, &mapper)
            } else {
                let view = OverlayView::new(&self.h, &self.overlay);
                let partitions = PartitionSet::build(&view, self.workers, self.max_units_per_item);
                self.run_query(&view, &partitions, query, &mapper)
            };

        // map back to original vertex ids
        let per_vertex = self.ordering.unapply_rows(&per_vertex_proc, n_classes);
        let elapsed = start.elapsed().as_secs_f64();

        let counts = MotifCounts {
            k,
            direction: query.direction,
            n: self.n,
            n_classes,
            per_vertex,
            class_ids: mapper.class_ids(),
            total_instances: instances,
            elapsed_secs: elapsed,
        };
        let report = RunReport {
            workers: metrics,
            total_instances: instances,
            elapsed_secs: elapsed,
            queue_items,
            queue_units,
            setup_secs: if reused { 0.0 } else { self.setup_secs },
            setup_reused: reused,
            tier_memory_bytes: self.h.tier_memory_bytes(),
        };
        Ok((counts, report))
    }

    /// Run one query over any probe surface (the cached CSR or the
    /// overlay view), returning processing-order rows.
    fn run_query<G: GraphProbe + Sync>(
        &self,
        h: &G,
        partitions: &PartitionSet,
        query: &CountQuery,
        mapper: &SlotMapper,
    ) -> (Vec<u64>, u64, Vec<WorkerMetrics>, usize, usize) {
        let workers = partitions.n_shards();
        let scheduler: Box<dyn Scheduler> = match query.scheduler {
            SchedulerMode::SharedCursor => {
                Box::new(SharedCursorScheduler::new(partitions.all_items()))
            }
            SchedulerMode::WorkStealing => {
                Box::new(WorkStealingScheduler::new(partitions.item_lists()))
            }
            SchedulerMode::WorkStealingBatch => {
                Box::new(WorkStealingScheduler::half_deque(partitions.item_lists()))
            }
        };
        let ranges = partitions.ranges();
        let sink = make_sink(query.sink, self.n, mapper.n_classes(), &ranges);

        let sched_ref: &dyn Scheduler = scheduler.as_ref();
        let sink_ref: &dyn CounterSink = sink.as_ref();
        let size = query.size;
        let dir = query.direction;
        let metrics: Vec<WorkerMetrics> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    s.spawn(move || worker_loop(h, size, dir, mapper, sched_ref, sink_ref, w))
                })
                .collect();
            handles.into_iter().map(|t| t.join().expect("worker panicked")).collect()
        });

        let (per_vertex_proc, instances) = sink.finish();
        (per_vertex_proc, instances, metrics, partitions.total_items, partitions.total_units)
    }

    // ----------------------------------------------------------- streaming

    /// Register an incrementally maintained per-vertex counter for (size,
    /// direction): one full count now, per-edge deltas afterwards.
    /// Idempotent for an already-maintained pair.
    pub fn maintain(&mut self, size: MotifSize, direction: Direction) -> Result<()> {
        if direction == Direction::Directed && !self.directed {
            bail!("directed motif maintenance requested on an undirected graph");
        }
        if self.maintained.iter().any(|m| m.size() == size && m.direction() == direction) {
            return Ok(());
        }
        let mapper = SlotMapper::new(size.k(), direction);
        let query = CountQuery { size, direction, ..Default::default() };
        let (rows, instances, _, _, _) = if self.overlay.is_empty() {
            self.run_query(&self.h, &self.partitions, &query, &mapper)
        } else {
            let view = OverlayView::new(&self.h, &self.overlay);
            let partitions = PartitionSet::build(&view, self.workers, self.max_units_per_item);
            self.run_query(&view, &partitions, &query, &mapper)
        };
        self.maintained.push(MaintainedCounts::new(size, direction, rows, instances));
        Ok(())
    }

    /// Read a maintained counter back as [`MotifCounts`] (original vertex
    /// ids). `None` when (size, direction) was never [`Session::maintain`]ed.
    /// This materializes all n × classes rows; point lookups should use
    /// [`Session::maintained_vertex`] instead.
    pub fn maintained_counts(&self, size: MotifSize, direction: Direction) -> Option<MotifCounts> {
        let m = self.maintained.iter().find(|m| m.size() == size && m.direction() == direction)?;
        let rows = self.ordering.unapply_rows(m.per_vertex(), m.n_classes());
        Some(m.to_counts(self.n, rows, 0.0))
    }

    /// One maintained counter row for one ORIGINAL vertex id — the
    /// O(classes) lookup the service's `VertexCounts` request serves
    /// from, with no n-sized materialization. `None` when (size,
    /// direction) is not maintained or `v` is out of range.
    pub fn maintained_vertex(
        &self,
        size: MotifSize,
        direction: Direction,
        v: u32,
    ) -> Option<&[u64]> {
        let m = self.maintained.iter().find(|m| m.size() == size && m.direction() == direction)?;
        if v as usize >= self.n {
            return None;
        }
        let pv = self.ordering.new_of_old[v as usize] as usize;
        let nc = m.n_classes();
        Some(&m.per_vertex()[pv * nc..(pv + 1) * nc])
    }

    /// Apply a batch of edge insertions/deletions (original vertex ids)
    /// without reloading: patch the overlay, re-enumerate only the motif
    /// instances containing each changed edge, and fold the deltas into
    /// every maintained counter. Ops on self-loops, out-of-range vertices,
    /// already-present inserts and absent deletes are counted as skipped.
    /// Compaction (CSR rebuild + partition refresh) triggers at the end of
    /// a batch that pushed the overlay past `compact_ratio`.
    pub fn apply_edges(&mut self, deltas: &[EdgeDelta]) -> Result<DeltaReport> {
        let t0 = Instant::now();
        let mut report = DeltaReport::default();
        let mut touched: HashSet<u32> = HashSet::new();
        let n = self.n as u32;
        for d in deltas {
            if d.u == d.v || d.u >= n || d.v >= n {
                report.skipped_invalid += 1;
                continue;
            }
            let pu = self.ordering.new_of_old[d.u as usize];
            let pv = self.ordering.new_of_old[d.v as usize];
            let bits_pre = {
                let view = OverlayView::new(&self.h, &self.overlay);
                if self.directed {
                    (view.out_has_edge(pu, pv) as u8) | ((view.out_has_edge(pv, pu) as u8) << 1)
                } else if view.und_has_edge(pu, pv) {
                    0b11
                } else {
                    0
                }
            };
            match d.op {
                DeltaOp::Insert => {
                    if self.directed {
                        if bits_pre & 0b01 != 0 {
                            report.skipped_duplicate += 1;
                            continue;
                        }
                        // patch first: the union state (und pair present)
                        // is the post state for insertions
                        self.overlay.insert_directed(&self.h, pu, pv, bits_pre == 0);
                        let ch =
                            EdgeChange { u: pu, v: pv, bits_pre, bits_post: bits_pre | 0b01 };
                        self.reenumerate(&ch, &mut report, &mut touched);
                    } else {
                        if bits_pre != 0 {
                            report.skipped_duplicate += 1;
                            continue;
                        }
                        self.overlay.insert_undirected(&self.h, pu, pv);
                        let ch = EdgeChange { u: pu, v: pv, bits_pre: 0, bits_post: 0b11 };
                        self.reenumerate(&ch, &mut report, &mut touched);
                    }
                    report.inserted += 1;
                }
                DeltaOp::Delete => {
                    if self.directed {
                        if bits_pre & 0b01 == 0 {
                            report.skipped_missing += 1;
                            continue;
                        }
                        let bits_post = bits_pre & 0b10;
                        let ch = EdgeChange { u: pu, v: pv, bits_pre, bits_post };
                        if bits_post == 0 {
                            // the pair's last direction goes away: the pre
                            // state is the union state — enumerate, THEN patch
                            self.reenumerate(&ch, &mut report, &mut touched);
                            self.overlay.delete_directed(&self.h, pu, pv, true);
                        } else {
                            // reciprocal edge remains: und structure intact
                            self.overlay.delete_directed(&self.h, pu, pv, false);
                            self.reenumerate(&ch, &mut report, &mut touched);
                        }
                    } else {
                        if bits_pre == 0 {
                            report.skipped_missing += 1;
                            continue;
                        }
                        let ch = EdgeChange { u: pu, v: pv, bits_pre: 0b11, bits_post: 0 };
                        self.reenumerate(&ch, &mut report, &mut touched);
                        self.overlay.delete_undirected(&self.h, pu, pv);
                    }
                    report.deleted += 1;
                }
            }
        }

        if !self.overlay.is_empty() && self.overlay.ratio(&self.h) > self.compact_ratio {
            self.h = self.overlay.compact(&self.h);
            if self.adjacency == AdjacencyMode::Hybrid {
                // the rebuilt CSR ships without bitmaps; re-tier it
                self.h.enable_hybrid(self.hub_threshold);
            }
            self.partitions = PartitionSet::build(&self.h, self.workers, self.max_units_per_item);
            self.compactions += 1;
            report.compactions += 1;
        }
        report.touched_vertices = touched.len();
        report.overlay_entries = self.overlay.entries();
        report.overlay_ratio = self.overlay.ratio(&self.h);
        report.elapsed_secs = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    fn reenumerate(
        &mut self,
        ch: &EdgeChange,
        report: &mut DeltaReport,
        touched: &mut HashSet<u32>,
    ) {
        if self.maintained.is_empty() {
            return;
        }
        let view = OverlayView::new(&self.h, &self.overlay);
        let stats = reenumerate_edge(
            &view,
            self.directed,
            ch,
            &mut self.maintained,
            self.workers,
            self.max_units_per_item,
            touched,
        );
        report.reenumerated_units += stats.units;
        report.reenumerated_sets += stats.sets;
    }

    /// Materialize the session's current graph (base + overlay) back into
    /// ORIGINAL vertex ids — the reload-and-recount oracle used by tests
    /// and `vdmc stream --verify`.
    pub fn snapshot_graph(&self) -> Graph {
        let proc = self.overlay.materialize(&self.h);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        if self.directed {
            for (u, v) in proc.out.edges() {
                edges.push((
                    self.ordering.old_of_new[u as usize],
                    self.ordering.old_of_new[v as usize],
                ));
            }
        } else {
            for (u, v) in proc.und.edges() {
                if u < v {
                    edges.push((
                        self.ordering.old_of_new[u as usize],
                        self.ordering.old_of_new[v as usize],
                    ));
                }
            }
        }
        Graph::from_edges(self.n, &edges, self.directed)
    }
}

fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Worker inner loop shared by every scheduler × sink combination and
/// every probe surface (static CSR or delta overlay): claim items until
/// drained, feed every enumerated instance to the sink handle.
fn worker_loop<G: GraphProbe + Sync>(
    h: &G,
    size: MotifSize,
    dir: Direction,
    mapper: &SlotMapper,
    sched: &dyn Scheduler,
    sink: &dyn CounterSink,
    worker_id: usize,
) -> WorkerMetrics {
    let mut m = WorkerMetrics { worker_id, ..Default::default() };
    let t0 = Instant::now();
    let mut handle = sink.worker(worker_id);
    let mut ctx = bfs3::EnumCtx::new(h.n());
    while let Some(claim) = sched.pop(worker_id) {
        let item = claim.item;
        m.items += 1;
        m.units += item.units() as u64;
        if claim.stolen {
            m.steals += 1;
            m.steal_batch += claim.batch as u64;
        }
        for j in item.j_start..item.j_end {
            match size {
                MotifSize::Three => {
                    bfs3::enumerate_unit(h, dir, item.root, j as usize, &mut ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        m.instances += 1;
                        handle.record(verts, slot);
                    });
                }
                MotifSize::Four => {
                    bfs4::enumerate_unit(h, dir, item.root, j as usize, &mut ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        m.instances += 1;
                        handle.record(verts, slot);
                    });
                }
            }
        }
    }
    handle.flush();
    m.busy_secs = t0.elapsed().as_secs_f64();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{count_motifs, CountConfig};
    use crate::graph::generators;

    #[test]
    fn session_reuse_skips_setup_and_matches_seed_path() {
        let g = generators::gnp_directed(80, 0.08, 41);
        let session = Session::load(&g);
        assert_eq!(session.queries_served(), 0);

        let q3 = CountQuery { size: MotifSize::Three, ..Default::default() };
        let (c1, r1) = session.count_with_report(&q3).unwrap();
        assert!(!r1.setup_reused);
        let (c2, r2) = session.count_with_report(&q3).unwrap();
        assert!(r2.setup_reused, "second query must reuse cached setup");
        assert_eq!(r2.setup_secs, 0.0);
        assert_eq!(session.queries_served(), 2);

        // identical to two independent seed-path calls
        let cfg = CountConfig { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() };
        let seed1 = count_motifs(&g, &cfg).unwrap();
        let seed2 = count_motifs(&g, &cfg).unwrap();
        assert_eq!(c1.per_vertex, seed1.per_vertex);
        assert_eq!(c2.per_vertex, seed2.per_vertex);
        assert_eq!(c1.total_instances, seed1.total_instances);
    }

    #[test]
    fn one_session_serves_mixed_queries() {
        let g = generators::gnp_directed(60, 0.1, 5);
        let session = Session::load(&g);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let got = session
                    .count(&CountQuery { size, direction: dir, ..Default::default() })
                    .unwrap();
                let want = count_motifs(
                    &g,
                    &CountConfig { size, direction: dir, ..Default::default() },
                )
                .unwrap();
                assert_eq!(got.per_vertex, want.per_vertex, "{size:?} {dir:?}");
            }
        }
        assert_eq!(session.queries_served(), 4);
    }

    #[test]
    fn every_scheduler_sink_combination_agrees() {
        let g = generators::barabasi_albert(150, 4, 3);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let base = session
            .count(&CountQuery {
                size: MotifSize::Four,
                direction: Direction::Undirected,
                scheduler: SchedulerMode::SharedCursor,
                sink: CounterMode::Atomic,
            })
            .unwrap();
        for scheduler in [
            SchedulerMode::SharedCursor,
            SchedulerMode::WorkStealing,
            SchedulerMode::WorkStealingBatch,
        ] {
            for sink in [CounterMode::Atomic, CounterMode::Sharded, CounterMode::PartitionLocal] {
                let got = session
                    .count(&CountQuery {
                        size: MotifSize::Four,
                        direction: Direction::Undirected,
                        scheduler,
                        sink,
                    })
                    .unwrap();
                assert_eq!(got.per_vertex, base.per_vertex, "{scheduler:?} {sink:?}");
                assert_eq!(got.total_instances, base.total_instances, "{scheduler:?} {sink:?}");
            }
        }
    }

    #[test]
    fn directed_query_on_undirected_session_is_error() {
        let g = generators::star(6);
        let session = Session::load(&g);
        let err = session.count(&CountQuery::default()).unwrap_err();
        assert!(err.to_string().contains("undirected"));
        let mut session = session;
        let err = session.maintain(MotifSize::Three, Direction::Directed).unwrap_err();
        assert!(err.to_string().contains("undirected"));
    }

    #[test]
    fn report_units_cover_graph_for_all_schedulers() {
        let g = generators::barabasi_albert(300, 3, 17);
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for scheduler in [
            SchedulerMode::SharedCursor,
            SchedulerMode::WorkStealing,
            SchedulerMode::WorkStealingBatch,
        ] {
            let (_, report) = session
                .count_with_report(&CountQuery {
                    size: MotifSize::Three,
                    direction: Direction::Undirected,
                    scheduler,
                    ..Default::default()
                })
                .unwrap();
            let worker_units: u64 = report.workers.iter().map(|w| w.units).sum();
            assert_eq!(worker_units as usize, report.queue_units);
            assert_eq!(report.queue_units, g.und.m() / 2);
            let worker_instances: u64 = report.workers.iter().map(|w| w.instances).sum();
            assert_eq!(worker_instances, report.total_instances);
        }
    }

    #[test]
    fn batch_stealing_records_batch_mass() {
        // star graph: all units on the hub shard, every other worker steals
        let g = generators::star(600);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let (_, report) = session
            .count_with_report(&CountQuery {
                size: MotifSize::Three,
                direction: Direction::Undirected,
                scheduler: SchedulerMode::WorkStealingBatch,
                ..Default::default()
            })
            .unwrap();
        // steal-batch mass >= steal count whenever any steal happened
        assert!(report.total_steal_batch() >= report.total_steals());
    }

    // -------------------------------------------------------- streaming

    #[test]
    fn apply_edges_matches_reload_small() {
        let g = generators::gnp_directed(40, 0.1, 13);
        let mut session =
            Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        session.maintain(MotifSize::Four, Direction::Undirected).unwrap();

        let deltas = vec![
            EdgeDelta::insert(0, 5),
            EdgeDelta::insert(5, 0),
            EdgeDelta::delete(0, 5),
            EdgeDelta::insert(7, 8),
            EdgeDelta::delete(1, 2),
            EdgeDelta::insert(3, 3),    // self loop: invalid
            EdgeDelta::insert(0, 1000), // out of range: invalid
        ];
        let report = session.apply_edges(&deltas).unwrap();
        assert!(report.skipped_invalid >= 2);

        let snapshot = session.snapshot_graph();
        let fresh = Session::load_with(&snapshot, &SessionConfig::default());
        for (size, dir) in
            [(MotifSize::Three, Direction::Directed), (MotifSize::Four, Direction::Undirected)]
        {
            let maintained = session.maintained_counts(size, dir).unwrap();
            let want = fresh.count(&CountQuery { size, direction: dir, ..Default::default() }).unwrap();
            assert_eq!(maintained.per_vertex, want.per_vertex, "{size:?} {dir:?}");
            assert_eq!(maintained.total_instances, want.total_instances);
        }
    }

    #[test]
    fn dirty_count_equals_compacted_count() {
        let g = generators::gnp_directed(50, 0.08, 21);
        // never compact automatically
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let deltas: Vec<EdgeDelta> =
            (0..20).map(|i| EdgeDelta::insert(i, (i * 7 + 3) % 50)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0, "overlay should be dirty");

        let q = CountQuery { size: MotifSize::Four, direction: Direction::Directed, ..Default::default() };
        let dirty = session.count(&q).unwrap();

        let snapshot = session.snapshot_graph();
        let fresh = Session::load(&snapshot);
        let want = fresh.count(&q).unwrap();
        assert_eq!(dirty.per_vertex, want.per_vertex);
        assert_eq!(dirty.total_instances, want.total_instances);
    }

    #[test]
    fn compaction_triggers_and_preserves_counts() {
        let g = generators::gnp_undirected(40, 0.1, 9);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: 0.0, ..Default::default() },
        );
        session.maintain(MotifSize::Three, Direction::Undirected).unwrap();
        let deltas: Vec<EdgeDelta> =
            (0..10u32).map(|i| EdgeDelta::insert(i, (i + 13) % 40)).collect();
        let report = session.apply_edges(&deltas).unwrap();
        if report.applied() > 0 {
            assert_eq!(report.compactions, 1, "ratio 0.0 must compact every dirty batch");
            assert_eq!(session.overlay_entries(), 0);
        }
        let snapshot = session.snapshot_graph();
        let fresh = Session::load(&snapshot);
        let q = CountQuery {
            size: MotifSize::Three,
            direction: Direction::Undirected,
            ..Default::default()
        };
        assert_eq!(
            session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap().per_vertex,
            fresh.count(&q).unwrap().per_vertex
        );
    }

    #[test]
    fn maintain_is_idempotent_and_listed() {
        let g = generators::gnp_directed(30, 0.1, 2);
        let mut session = Session::load(&g);
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        assert_eq!(session.maintained().len(), 1);
        assert!(session.maintained_counts(MotifSize::Four, Direction::Directed).is_none());
        let c = session.maintained_counts(MotifSize::Three, Direction::Directed).unwrap();
        let want = session
            .count(&CountQuery { size: MotifSize::Three, ..Default::default() })
            .unwrap();
        assert_eq!(c.per_vertex, want.per_vertex);
    }

    #[test]
    fn adjacency_tiers_agree_and_report_memory() {
        let g = generators::barabasi_albert_directed(200, 4, 0.3, 12);
        let csr = Session::load_with(
            &g,
            &SessionConfig { workers: 2, adjacency: AdjacencyMode::Csr, ..Default::default() },
        );
        let hybrid = Session::load_with(
            &g,
            &SessionConfig {
                workers: 2,
                adjacency: AdjacencyMode::Hybrid,
                hub_threshold: Some(4),
                ..Default::default()
            },
        );
        assert_eq!(csr.tier_memory_bytes(), 0);
        assert!(hybrid.tier_memory_bytes() > 0);
        assert!(hybrid.hub_rows() > 0);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let q = CountQuery { size, direction: dir, ..Default::default() };
                let (a, ra) = csr.count_with_report(&q).unwrap();
                let (b, rb) = hybrid.count_with_report(&q).unwrap();
                assert_eq!(a.per_vertex, b.per_vertex, "{size:?} {dir:?}");
                assert_eq!(a.total_instances, b.total_instances);
                assert_eq!(ra.tier_memory_bytes, 0);
                assert_eq!(rb.tier_memory_bytes, hybrid.tier_memory_bytes());
            }
        }
    }

    #[test]
    fn compaction_rebuilds_hybrid_tier() {
        let g = generators::gnp_directed(40, 0.1, 33);
        let mut session = Session::load_with(
            &g,
            &SessionConfig {
                workers: 2,
                compact_ratio: 0.0, // compact every dirty batch
                hub_threshold: Some(2),
                ..Default::default()
            },
        );
        let before = session.tier_memory_bytes();
        assert!(before > 0);
        let deltas: Vec<EdgeDelta> =
            (0..12u32).map(|i| EdgeDelta::insert(i, (i + 17) % 40)).collect();
        let report = session.apply_edges(&deltas).unwrap();
        assert!(report.compactions >= 1);
        assert!(
            session.tier_memory_bytes() > 0,
            "compaction must re-tier the rebuilt CSR"
        );
        // counts over the re-tiered CSR still match a fresh reload
        let q = CountQuery { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() };
        let fresh = Session::load(&session.snapshot_graph());
        assert_eq!(
            session.count(&q).unwrap().per_vertex,
            fresh.count(&q).unwrap().per_vertex
        );
    }

    #[test]
    fn maintained_vertex_matches_materialized_rows() {
        let g = generators::gnp_directed(35, 0.1, 29);
        let mut session = Session::load(&g);
        let (size, dir) = (MotifSize::Three, Direction::Directed);
        assert!(session.maintained_vertex(size, dir, 0).is_none(), "nothing maintained yet");
        session.maintain(size, dir).unwrap();
        session.apply_edges(&[EdgeDelta::insert(0, 9), EdgeDelta::delete(1, 2)]).unwrap();
        let full = session.maintained_counts(size, dir).unwrap();
        for v in 0..g.n() as u32 {
            assert_eq!(session.maintained_vertex(size, dir, v).unwrap(), full.vertex(v), "v{v}");
        }
        assert!(session.maintained_vertex(size, dir, g.n() as u32).is_none(), "out of range");
        assert_eq!(session.n(), g.n());
    }

    #[test]
    fn builder_parses_cli_spellings_and_rejects_bad_ones() {
        let q = CountQuery::builder()
            .size_k(4)
            .direction_name("undirected")
            .scheduler_name("stealing-batch")
            .sink_name("partition")
            .build()
            .unwrap();
        assert_eq!(q.size, MotifSize::Four);
        assert_eq!(q.direction, Direction::Undirected);
        assert_eq!(q.scheduler, SchedulerMode::WorkStealingBatch);
        assert_eq!(q.sink, CounterMode::PartitionLocal);

        // defaults match CountQuery::default()
        let d = CountQuery::builder().build().unwrap();
        assert_eq!(d.size, CountQuery::default().size);
        assert_eq!(d.scheduler, CountQuery::default().scheduler);

        assert!(CountQuery::builder().size_k(5).build().is_err());
        assert!(CountQuery::builder().direction_name("sideways").build().is_err());
        assert!(CountQuery::builder().scheduler_name("fifo").build().is_err());
        assert!(CountQuery::builder().sink_name("tree").build().is_err());
        // first error wins and names the bad knob
        let err = CountQuery::builder()
            .size_k(9)
            .scheduler_name("fifo")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("3 or 4"), "{err}");
    }

    #[test]
    fn memory_bytes_tracks_session_state() {
        let g = generators::gnp_directed(60, 0.1, 7);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let base = session.memory_bytes();
        assert!(base >= g.und.memory_bytes(), "must cover at least the und CSR");

        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        let with_counter = session.memory_bytes();
        assert!(with_counter > base, "maintained counters must be accounted");

        let deltas: Vec<EdgeDelta> =
            (0..15u32).map(|i| EdgeDelta::insert(i, (i + 23) % 60)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0);
        assert!(
            session.memory_bytes() > with_counter,
            "a dirty overlay must grow the accounted bytes"
        );
    }

    #[test]
    fn graph_id_identity() {
        let g = generators::star(6);
        let mut session = Session::load(&g);
        assert_eq!(session.graph_id(), None);
        session.set_graph_id("stars/6");
        assert_eq!(session.graph_id(), Some("stars/6"));
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let g = generators::star(8);
        let mut session = Session::load(&g);
        session.maintain(MotifSize::Three, Direction::Undirected).unwrap();
        let before = session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap();
        let report = session.apply_edges(&[]).unwrap();
        assert_eq!(report.applied(), 0);
        assert_eq!(report.reenumerated_units, 0);
        let after = session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap();
        assert_eq!(before.per_vertex, after.per_vertex);
    }
}
