//! Session layer: load once, query many times.
//!
//! [`Session::load`] performs every input-only computation once — the
//! Section 6 degree-descending relabeling, the relabeled CSR (with its
//! undirected view and transpose), and the degree-mass-balanced
//! [`PartitionSet`] — and then serves repeated [`CountQuery`]s against the
//! cached state. This is what makes repeated queries cheap: the seed
//! coordinator rebuilt ordering, queue and counters on every call, so a
//! serving deployment paid full setup cost per request.
//!
//! Every query picks its own motif size, direction, scheduler and sink;
//! the per-query state (scheduler queues, counter arrays) is rebuilt from
//! the cached partition in O(items + n·classes), with no graph passes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::metrics::{RunReport, WorkerMetrics};
use crate::graph::csr::Graph;
use crate::graph::ordering::VertexOrdering;
use crate::motifs::counter::{CounterMode, MotifCounts, SlotMapper};
use crate::motifs::iso::NO_SLOT;
use crate::motifs::{bfs3, bfs4, Direction, MotifSize};

use super::partition::PartitionSet;
use super::scheduler::{Scheduler, SchedulerMode, SharedCursorScheduler, WorkStealingScheduler};
use super::sink::{make_sink, CounterSink};

/// Load-time configuration (everything a query may NOT change, because the
/// cached partition depends on it).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Worker threads = shard count; 0 = one per available core.
    pub workers: usize,
    /// Relabel by descending degree before counting (paper Section 6).
    pub reorder: bool,
    /// Max (root, neighbor) units per work item.
    pub max_units_per_item: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { workers: 0, reorder: true, max_units_per_item: 64 }
    }
}

/// One counting request against a loaded session.
#[derive(Debug, Clone)]
pub struct CountQuery {
    pub size: MotifSize,
    pub direction: Direction,
    pub scheduler: SchedulerMode,
    pub sink: CounterMode,
}

impl Default for CountQuery {
    fn default() -> Self {
        CountQuery {
            size: MotifSize::Three,
            direction: Direction::Directed,
            scheduler: SchedulerMode::WorkStealing,
            sink: CounterMode::Sharded,
        }
    }
}

/// A graph loaded for repeated motif counting: cached ordering, relabeled
/// CSR and partition set.
pub struct Session {
    directed: bool,
    n: usize,
    ordering: VertexOrdering,
    /// Relabeled graph (processing ids).
    h: Graph,
    partitions: PartitionSet,
    setup_secs: f64,
    served: AtomicUsize,
}

impl Session {
    /// Load with default configuration.
    pub fn load(graph: &Graph) -> Session {
        Session::load_with(graph, &SessionConfig::default())
    }

    /// Load: relabel, build the undirected/transpose views, partition.
    /// All of it happens exactly once per session.
    pub fn load_with(graph: &Graph, cfg: &SessionConfig) -> Session {
        let t0 = Instant::now();
        let n = graph.n();
        let ordering = if cfg.reorder {
            VertexOrdering::degree_descending(graph)
        } else {
            VertexOrdering::identity(n)
        };
        let h = ordering.apply(graph);
        let workers = resolve_workers(cfg.workers);
        let partitions = PartitionSet::build(&h, workers, cfg.max_units_per_item.max(1));
        Session {
            directed: graph.directed,
            n,
            ordering,
            h,
            partitions,
            setup_secs: t0.elapsed().as_secs_f64(),
            served: AtomicUsize::new(0),
        }
    }

    /// Worker threads (= shard count) queries run with.
    pub fn workers(&self) -> usize {
        self.partitions.n_shards()
    }

    /// Wall-clock seconds the one-time setup took.
    pub fn setup_secs(&self) -> f64 {
        self.setup_secs
    }

    /// Queries served so far.
    pub fn queries_served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    pub fn partitions(&self) -> &PartitionSet {
        &self.partitions
    }

    /// Count all k-motifs per vertex for one query.
    pub fn count(&self, query: &CountQuery) -> Result<MotifCounts> {
        Ok(self.count_with_report(query)?.0)
    }

    /// As [`Session::count`], also returning the run report. The report's
    /// `setup_secs`/`setup_reused` show whether this call paid for setup
    /// (first query) or served from cache.
    pub fn count_with_report(&self, query: &CountQuery) -> Result<(MotifCounts, RunReport)> {
        if query.direction == Direction::Directed && !self.directed {
            bail!("directed motif counting requested on an undirected graph");
        }
        let reused = self.served.fetch_add(1, Ordering::Relaxed) > 0;
        let start = Instant::now();
        let k = query.size.k();
        let mapper = SlotMapper::new(k, query.direction);
        let n_classes = mapper.n_classes();
        let workers = self.partitions.n_shards();

        let scheduler: Box<dyn Scheduler> = match query.scheduler {
            SchedulerMode::SharedCursor => {
                Box::new(SharedCursorScheduler::new(self.partitions.all_items()))
            }
            SchedulerMode::WorkStealing => {
                Box::new(WorkStealingScheduler::new(self.partitions.item_lists()))
            }
        };
        let ranges = self.partitions.ranges();
        let sink = make_sink(query.sink, self.n, n_classes, &ranges);

        let sched_ref: &dyn Scheduler = scheduler.as_ref();
        let sink_ref: &dyn CounterSink = sink.as_ref();
        let h = &self.h;
        let size = query.size;
        let dir = query.direction;
        let metrics: Vec<WorkerMetrics> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let mapper = &mapper;
                    s.spawn(move || worker_loop(h, size, dir, mapper, sched_ref, sink_ref, w))
                })
                .collect();
            handles.into_iter().map(|t| t.join().expect("worker panicked")).collect()
        });

        let (per_vertex_proc, instances) = sink.finish();
        // map back to original vertex ids
        let per_vertex = self.ordering.unapply_rows(&per_vertex_proc, n_classes);
        let elapsed = start.elapsed().as_secs_f64();

        let counts = MotifCounts {
            k,
            direction: query.direction,
            n: self.n,
            n_classes,
            per_vertex,
            class_ids: mapper.class_ids(),
            total_instances: instances,
            elapsed_secs: elapsed,
        };
        let report = RunReport {
            workers: metrics,
            total_instances: instances,
            elapsed_secs: elapsed,
            queue_items: self.partitions.total_items,
            queue_units: self.partitions.total_units,
            setup_secs: if reused { 0.0 } else { self.setup_secs },
            setup_reused: reused,
        };
        Ok((counts, report))
    }
}

fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Worker inner loop shared by every scheduler × sink combination: claim
/// items until drained, feed every enumerated instance to the sink handle.
fn worker_loop(
    h: &Graph,
    size: MotifSize,
    dir: Direction,
    mapper: &SlotMapper,
    sched: &dyn Scheduler,
    sink: &dyn CounterSink,
    worker_id: usize,
) -> WorkerMetrics {
    let mut m = WorkerMetrics { worker_id, ..Default::default() };
    let t0 = Instant::now();
    let mut handle = sink.worker(worker_id);
    let mut ctx = bfs3::EnumCtx::new(h.n());
    while let Some(claim) = sched.pop(worker_id) {
        let item = claim.item;
        m.items += 1;
        m.units += item.units() as u64;
        if claim.stolen {
            m.steals += 1;
        }
        for j in item.j_start..item.j_end {
            match size {
                MotifSize::Three => {
                    bfs3::enumerate_unit(h, dir, item.root, j as usize, &mut ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        m.instances += 1;
                        handle.record(verts, slot);
                    });
                }
                MotifSize::Four => {
                    bfs4::enumerate_unit(h, dir, item.root, j as usize, &mut ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        m.instances += 1;
                        handle.record(verts, slot);
                    });
                }
            }
        }
    }
    handle.flush();
    m.busy_secs = t0.elapsed().as_secs_f64();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{count_motifs, CountConfig};
    use crate::graph::generators;

    #[test]
    fn session_reuse_skips_setup_and_matches_seed_path() {
        let g = generators::gnp_directed(80, 0.08, 41);
        let session = Session::load(&g);
        assert_eq!(session.queries_served(), 0);

        let q3 = CountQuery { size: MotifSize::Three, ..Default::default() };
        let (c1, r1) = session.count_with_report(&q3).unwrap();
        assert!(!r1.setup_reused);
        let (c2, r2) = session.count_with_report(&q3).unwrap();
        assert!(r2.setup_reused, "second query must reuse cached setup");
        assert_eq!(r2.setup_secs, 0.0);
        assert_eq!(session.queries_served(), 2);

        // identical to two independent seed-path calls
        let cfg = CountConfig { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() };
        let seed1 = count_motifs(&g, &cfg).unwrap();
        let seed2 = count_motifs(&g, &cfg).unwrap();
        assert_eq!(c1.per_vertex, seed1.per_vertex);
        assert_eq!(c2.per_vertex, seed2.per_vertex);
        assert_eq!(c1.total_instances, seed1.total_instances);
    }

    #[test]
    fn one_session_serves_mixed_queries() {
        let g = generators::gnp_directed(60, 0.1, 5);
        let session = Session::load(&g);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let got = session
                    .count(&CountQuery { size, direction: dir, ..Default::default() })
                    .unwrap();
                let want = count_motifs(
                    &g,
                    &CountConfig { size, direction: dir, ..Default::default() },
                )
                .unwrap();
                assert_eq!(got.per_vertex, want.per_vertex, "{size:?} {dir:?}");
            }
        }
        assert_eq!(session.queries_served(), 4);
    }

    #[test]
    fn every_scheduler_sink_combination_agrees() {
        let g = generators::barabasi_albert(150, 4, 3);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let base = session
            .count(&CountQuery {
                size: MotifSize::Four,
                direction: Direction::Undirected,
                scheduler: SchedulerMode::SharedCursor,
                sink: CounterMode::Atomic,
            })
            .unwrap();
        for scheduler in [SchedulerMode::SharedCursor, SchedulerMode::WorkStealing] {
            for sink in [CounterMode::Atomic, CounterMode::Sharded, CounterMode::PartitionLocal] {
                let got = session
                    .count(&CountQuery {
                        size: MotifSize::Four,
                        direction: Direction::Undirected,
                        scheduler,
                        sink,
                    })
                    .unwrap();
                assert_eq!(got.per_vertex, base.per_vertex, "{scheduler:?} {sink:?}");
                assert_eq!(got.total_instances, base.total_instances, "{scheduler:?} {sink:?}");
            }
        }
    }

    #[test]
    fn directed_query_on_undirected_session_is_error() {
        let g = generators::star(6);
        let session = Session::load(&g);
        let err = session.count(&CountQuery::default()).unwrap_err();
        assert!(err.to_string().contains("undirected"));
    }

    #[test]
    fn report_units_cover_graph_for_all_schedulers() {
        let g = generators::barabasi_albert(300, 3, 17);
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for scheduler in [SchedulerMode::SharedCursor, SchedulerMode::WorkStealing] {
            let (_, report) = session
                .count_with_report(&CountQuery {
                    size: MotifSize::Three,
                    direction: Direction::Undirected,
                    scheduler,
                    ..Default::default()
                })
                .unwrap();
            let worker_units: u64 = report.workers.iter().map(|w| w.units).sum();
            assert_eq!(worker_units as usize, report.queue_units);
            assert_eq!(report.queue_units, g.und.m() / 2);
            let worker_instances: u64 = report.workers.iter().map(|w| w.instances).sum();
            assert_eq!(worker_instances, report.total_instances);
        }
    }
}
