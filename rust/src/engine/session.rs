//! Session layer: load once, query many times, mutate in place.
//!
//! [`Session::load`] performs every input-only computation once — the
//! Section 6 degree-descending relabeling, the relabeled CSR (with its
//! undirected view and transpose), and the degree-mass-balanced
//! [`PartitionSet`] — and then serves repeated [`MotifQuery`]s against the
//! cached state. This is what makes repeated queries cheap: the seed
//! coordinator rebuilt ordering, queue and counters on every call, so a
//! serving deployment paid full setup cost per request.
//!
//! [`Session::query`] is the general entry point: one call covers every
//! [`Output`] kind (per-vertex counts, materialized instances, per-class
//! reservoir samples, top-vertex rankings) and every [`Scope`] (whole
//! graph, explicit vertex sets, seed neighborhoods). Scoping happens at
//! the **work-unit level** — the root of a k-set is its minimal member
//! and a connected k-set has diameter ≤ k-1, so only units whose root
//! lies in the (k-1)-hop ball around the scope set are enumerated; a
//! per-instance membership test then keeps exactly the instances that
//! touch the scope. [`Session::count`] remains the Counts-only shorthand.
//!
//! Since the stream layer landed, a session is also *live*:
//! [`Session::maintain`] registers a (size, direction) counter,
//! [`Session::apply_edges`] applies a batch of edge insertions/deletions
//! by patching the delta overlay and re-enumerating only the instances
//! containing each changed edge, and [`Session::maintained_counts`] reads
//! the incrementally maintained per-vertex counts back. Maintenance is
//! **Count-only**: [`Session::maintain_query`] rejects any other output
//! (or a scope) with the typed [`CountOnlyError`] — instance lists and
//! samples don't invert under deletions, so they must run as full
//! queries, which stay exact while deltas are pending (the enumerators
//! run over the overlay view — same code path, see
//! [`crate::graph::GraphProbe`] — with a freshly budgeted partition).
//! Once the overlay outgrows `SessionConfig::compact_ratio` the CSR is
//! rebuilt (counting-sort bucket build) and the cached partitions
//! refreshed.
//!
//! Every query picks its own motif size, direction, scheduler, sink,
//! output and scope; the per-query state (scheduler queues, sink
//! accumulators) is rebuilt from the cached partition in
//! O(items + n·classes), with no graph passes.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::metrics::{PhaseSecs, RunReport, WorkerMetrics};
use crate::graph::csr::Graph;
use crate::graph::ordering::VertexOrdering;
use crate::graph::{AdjacencyMode, GraphProbe};
use crate::motifs::counter::{MotifCounts, SlotMapper};
use crate::motifs::iso::NO_SLOT;
use crate::motifs::{bfs3, bfs4, Direction, MotifSize};
use crate::service::faults;
use crate::stream::delta::{reenumerate_edge, CountOnlyError, EdgeChange, MaintainedCounts};
use crate::stream::overlay::{DeltaOverlay, OverlayView};
use crate::stream::{DeltaOp, DeltaReport, EdgeDelta};
use crate::telemetry::trace;

use super::cancel::{
    AbortReason, CancelToken, QueryAborted, CANCELLED_TOTAL, DEADLINE_EXCEEDED_TOTAL,
    HELP_CANCELLED, HELP_DEADLINE_EXCEEDED, HELP_PANICS_CAUGHT, PANICS_CAUGHT_TOTAL,
};

use super::partition::{total_units, PartitionSet, WorkItem};
use super::query::{
    ClassSample, InstanceList, MotifInstance, MotifQuery, Output, QueryOutput, SampleSummary,
    Scope, TopVertices, VertexBits,
};
use super::scheduler::{Scheduler, SchedulerMode, SharedCursorScheduler, WorkStealingScheduler};
use super::sink::{
    CountEnumSink, EmitHandle, EnumSink, InstanceEnumSink, InstanceRec, MotifEvent,
    SampleEnumSink, TopVerticesEnumSink,
};

/// Load-time configuration (everything a query may NOT change, because the
/// cached partition depends on it).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Worker threads = shard count; 0 = one per available core.
    pub workers: usize,
    /// Relabel by descending degree before counting (paper Section 6).
    pub reorder: bool,
    /// Max (root, neighbor) units per work item.
    pub max_units_per_item: usize,
    /// Rebuild the CSR once the delta overlay's side-list occupancy
    /// exceeds this fraction of the base adjacency (checked per
    /// `apply_edges` batch). 0.0 compacts after every dirty batch.
    pub compact_ratio: f64,
    /// Adjacency tier the probes answer through: pure CSR, or CSR plus
    /// bitmap hub rows (the hybrid hot path). Rebuilt after compaction.
    pub adjacency: AdjacencyMode,
    /// Hub degree threshold for the hybrid tier; `None` picks
    /// [`crate::graph::Csr::default_hub_threshold`] (≈ √m).
    pub hub_threshold: Option<usize>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            workers: 0,
            reorder: true,
            max_units_per_item: 64,
            compact_ratio: 0.25,
            adjacency: AdjacencyMode::Hybrid,
            hub_threshold: None,
        }
    }
}

/// The resolved scope of one query, in processing ids: the member set
/// (instances must touch it) and the root set (units whose root can own a
/// member-touching instance — the (k-1)-hop ball around the members).
struct ScopeSets {
    members: VertexBits,
    roots: VertexBits,
}

/// An immutable, epoch-stamped capture of one session's complete read
/// state: relabeled CSR + hub tier, vertex ordering, cached partitions,
/// the frozen delta overlay and the maintained counters — everything a
/// query touches, shared behind `Arc`s. Snapshots are never mutated:
/// writers commit a *successor* snapshot into the session's
/// [`SnapshotCell`] (copy-on-write of the overlay side-lists and the
/// counters; the CSR, hub tier, ordering and partitions are shared
/// untouched except across a compaction). Any number of readers may
/// hold and query one snapshot concurrently — `Arc<SessionSnapshot>`
/// is `Send + Sync` and pinning is one refcount bump — and a reader
/// that pinned epoch `e` keeps answering from epoch `e` no matter how
/// many batches commit meanwhile.
pub struct SessionSnapshot {
    directed: bool,
    n: usize,
    /// Commit counter: 0 at load, +1 per committed write batch.
    epoch: u64,
    ordering: Arc<VertexOrdering>,
    /// Relabeled base graph (processing ids); patched by `overlay`.
    h: Arc<Graph>,
    partitions: Arc<PartitionSet>,
    /// Edge patches frozen at this epoch (empty right after load or
    /// compaction).
    overlay: Arc<DeltaOverlay>,
    /// Maintained per-vertex counters frozen at this epoch.
    maintained: Arc<Vec<MaintainedCounts>>,
    /// Requested worker count (pre-clamping), reused on rebuilds.
    workers: usize,
    max_units_per_item: usize,
    setup_secs: f64,
    /// Queries served, shared across every epoch of the session.
    served: Arc<AtomicUsize>,
}

/// The session's snapshot cell: the generic model-checked
/// [`snapshot::SnapshotCell`](crate::engine::snapshot::SnapshotCell)
/// instantiated with [`SessionSnapshot`]. Readers pin the head (an
/// `Arc` clone under a read lock held only for the pointer copy);
/// writers commit a successor with a pointer swap. Readers therefore
/// never wait on an in-flight write batch, and writers never wait on
/// in-flight queries — see `engine::snapshot` for the full protocol.
pub type SnapshotCell = crate::engine::snapshot::SnapshotCell<SessionSnapshot>;

impl crate::engine::snapshot::Snapshot for SessionSnapshot {
    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn memory_bytes(&self) -> usize {
        SessionSnapshot::memory_bytes(self)
    }

    fn retained_vs(&self, head: &SessionSnapshot) -> usize {
        SessionSnapshot::retained_vs(self, head)
    }
}

/// A graph loaded for repeated motif queries and live edge updates.
///
/// The session is a thin writer head over a [`SnapshotCell`]: every
/// read (`query`/`count`/`maintained_counts`/...) pins the current
/// [`SessionSnapshot`] and runs on it, and every write
/// (`maintain`/`apply_edges`) prepares a successor snapshot
/// copy-on-write and commits it with a pointer swap. Concurrent
/// readers hold snapshots via [`Session::snapshot`] or the shared cell
/// from [`Session::share`]; nothing a reader pinned can be freed under
/// it.
pub struct Session {
    /// Shared head; [`Session::share`] hands it to concurrent readers.
    cell: Arc<SnapshotCell>,
    compact_ratio: f64,
    /// Adjacency tier; the hybrid bitmap rows are rebuilt on compaction.
    adjacency: AdjacencyMode,
    hub_threshold: Option<usize>,
    compactions: usize,
    /// Pool identity: which graph this session serves. `None` for
    /// hand-built sessions outside a [`crate::service::SessionPool`].
    graph_id: Option<String>,
}

impl Session {
    /// Load with default configuration.
    pub fn load(graph: &Graph) -> Session {
        Session::load_with(graph, &SessionConfig::default())
    }

    /// Load: relabel, build the undirected/transpose views, partition.
    /// All of it happens exactly once per session; the result becomes
    /// the epoch-0 snapshot.
    pub fn load_with(graph: &Graph, cfg: &SessionConfig) -> Session {
        let t0 = Instant::now();
        let n = graph.n();
        let ordering = if cfg.reorder {
            VertexOrdering::degree_descending(graph)
        } else {
            VertexOrdering::identity(n)
        };
        let mut h = ordering.apply(graph);
        if cfg.adjacency == AdjacencyMode::Hybrid {
            h.enable_hybrid(cfg.hub_threshold);
        }
        let workers = resolve_workers(cfg.workers);
        let max_units_per_item = cfg.max_units_per_item.max(1);
        let partitions = PartitionSet::build(&h, workers, max_units_per_item);
        let snap = SessionSnapshot {
            directed: graph.directed,
            n,
            epoch: 0,
            ordering: Arc::new(ordering),
            h: Arc::new(h),
            partitions: Arc::new(partitions),
            overlay: Arc::new(DeltaOverlay::new()),
            maintained: Arc::new(Vec::new()),
            workers,
            max_units_per_item,
            setup_secs: t0.elapsed().as_secs_f64(),
            served: Arc::new(AtomicUsize::new(0)),
        };
        Session {
            cell: Arc::new(SnapshotCell::new(Arc::new(snap))),
            compact_ratio: cfg.compact_ratio.max(0.0),
            adjacency: cfg.adjacency,
            hub_threshold: cfg.hub_threshold,
            compactions: 0,
            graph_id: None,
        }
    }

    /// Tag this session with the graph id it serves (pool identity).
    pub fn set_graph_id(&mut self, id: impl Into<String>) {
        self.graph_id = Some(id.into());
    }

    /// The graph id this session serves, when pooled.
    pub fn graph_id(&self) -> Option<&str> {
        self.graph_id.as_deref()
    }

    /// Rebuild a fresh writer over this session's last *committed*
    /// state. Commits are atomic pointer swaps, so a writer that
    /// panicked mid-batch (poisoning its service-side mutex) left the
    /// snapshot cell at the previous consistent head; the recovered
    /// writer shares that cell — epochs, overlay and maintained
    /// counters are exactly the last commit — and bumps the epoch with
    /// an otherwise-identical successor so the recovery is observable.
    /// The service swaps this into the pool in place of the poisoned
    /// writer (see `SessionPool::replace_writer`).
    pub fn recover(&self) -> Session {
        let head = self.cell.head();
        self.cell.commit(head.next(None, None, None, None));
        Session {
            cell: self.cell.clone(),
            compact_ratio: self.compact_ratio,
            adjacency: self.adjacency,
            hub_threshold: self.hub_threshold,
            compactions: self.compactions,
            graph_id: self.graph_id.clone(),
        }
    }

    // ------------------------------------------------------- snapshots

    /// Pin the current snapshot: an immutable, `Send + Sync` view every
    /// read method also exists on. Queries against it are unaffected by
    /// concurrent `apply_edges`/`maintain` commits.
    pub fn snapshot(&self) -> Arc<SessionSnapshot> {
        self.cell.head()
    }

    /// The shared snapshot cell — hand this to concurrent readers (the
    /// service pins per-request snapshots through it without touching
    /// the writer lock).
    pub fn share(&self) -> Arc<SnapshotCell> {
        self.cell.clone()
    }

    /// Epoch of the current head snapshot: 0 at load, +1 per committed
    /// write batch.
    pub fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// Snapshots currently pinned by readers (head + superseded).
    pub fn pinned_snapshots(&self) -> usize {
        self.cell.pinned_snapshots()
    }

    /// Bytes kept alive by superseded-but-pinned epochs.
    pub fn retained_bytes(&self) -> usize {
        self.cell.retained_bytes()
    }

    // ------------------------------------------------------- accessors

    /// Vertex count of the loaded graph.
    pub fn n(&self) -> usize {
        self.cell.head().n
    }

    /// Worker threads (= shard count) queries run with.
    pub fn workers(&self) -> usize {
        self.cell.head().workers()
    }

    /// Wall-clock seconds the one-time setup took.
    pub fn setup_secs(&self) -> f64 {
        self.cell.head().setup_secs
    }

    /// Queries served so far (across all epochs).
    pub fn queries_served(&self) -> usize {
        self.cell.head().queries_served()
    }

    /// The cached partition set of the current snapshot.
    pub fn partitions(&self) -> Arc<PartitionSet> {
        self.cell.head().partitions.clone()
    }

    /// Pending overlay side-list entries (0 when fully compacted).
    pub fn overlay_entries(&self) -> usize {
        self.cell.head().overlay_entries()
    }

    /// Overlay occupancy relative to the base CSR.
    pub fn overlay_ratio(&self) -> f64 {
        self.cell.head().overlay_ratio()
    }

    /// CSR rebuilds performed by `apply_edges` so far.
    pub fn compactions(&self) -> usize {
        self.compactions
    }

    /// Adjacency tier this session's probes answer through.
    pub fn adjacency(&self) -> AdjacencyMode {
        self.adjacency
    }

    /// Bytes held by the hybrid bitmap tier (0 under [`AdjacencyMode::Csr`]).
    pub fn tier_memory_bytes(&self) -> usize {
        self.cell.head().tier_memory_bytes()
    }

    /// Bitmap hub rows of the relabeled undirected view.
    pub fn hub_rows(&self) -> usize {
        self.cell.head().hub_rows()
    }

    /// Total resident bytes of this session: the head snapshot (CSR
    /// views + hub tier, overlay, partitions, maintained counters,
    /// ordering) **plus** superseded epochs still pinned by readers.
    /// This is the number the [`crate::service::SessionPool`] byte
    /// budget meters — pinned history is resident memory too.
    pub fn memory_bytes(&self) -> usize {
        self.cell.resident_bytes()
    }

    /// The incrementally maintained counters of the current snapshot.
    pub fn maintained(&self) -> Arc<Vec<MaintainedCounts>> {
        self.cell.head().maintained.clone()
    }

    // ------------------------------------------------- delegated reads

    /// Run one query — any [`Output`], any [`Scope`] — on the current
    /// snapshot.
    pub fn query(&self, query: &MotifQuery) -> Result<QueryOutput> {
        self.cell.head().query(query)
    }

    /// As [`Session::query`], also returning the run report.
    pub fn query_with_report(&self, query: &MotifQuery) -> Result<(QueryOutput, RunReport)> {
        self.cell.head().query_with_report(query)
    }

    /// Count all k-motifs per vertex — the [`Output::Counts`] shorthand.
    pub fn count(&self, query: &MotifQuery) -> Result<MotifCounts> {
        self.cell.head().count(query)
    }

    /// As [`Session::count`], also returning the run report.
    pub fn count_with_report(&self, query: &MotifQuery) -> Result<(MotifCounts, RunReport)> {
        self.cell.head().count_with_report(query)
    }

    /// The closed `radius`-hop undirected neighborhood of `seeds`, in
    /// ORIGINAL vertex ids (sorted), over the current snapshot.
    pub fn neighborhood(&self, seeds: &[u32], radius: usize) -> Result<Vec<u32>> {
        self.cell.head().neighborhood(seeds, radius)
    }

    /// Read a maintained counter back as [`MotifCounts`] (original
    /// vertex ids). `None` when (size, direction) was never
    /// [`Session::maintain`]ed.
    pub fn maintained_counts(&self, size: MotifSize, direction: Direction) -> Option<MotifCounts> {
        self.cell.head().maintained_counts(size, direction)
    }

    /// One maintained counter row for one ORIGINAL vertex id. `None`
    /// when (size, direction) is not maintained or `v` is out of range.
    /// (Readers holding a pinned [`SessionSnapshot`] can borrow the row
    /// without this copy.)
    pub fn maintained_vertex(
        &self,
        size: MotifSize,
        direction: Direction,
        v: u32,
    ) -> Option<Vec<u64>> {
        self.cell.head().maintained_vertex(size, direction, v).map(<[u64]>::to_vec)
    }

    /// Materialize the session's current graph (base + overlay) back
    /// into ORIGINAL vertex ids — the reload-and-recount oracle used by
    /// tests and `vdmc stream --verify`.
    pub fn snapshot_graph(&self) -> Graph {
        self.cell.head().snapshot_graph()
    }

    // -------------------------------------------------------- writers

    /// Register an incrementally maintained per-vertex counter for (size,
    /// direction): one full count now, per-edge deltas afterwards.
    /// Idempotent for an already-maintained pair. Commits a new epoch.
    pub fn maintain(&mut self, size: MotifSize, direction: Direction) -> Result<()> {
        let head = self.cell.head();
        if direction == Direction::Directed && !head.directed {
            bail!("directed motif maintenance requested on an undirected graph");
        }
        if head.maintained.iter().any(|m| m.size() == size && m.direction() == direction) {
            return Ok(());
        }
        let mapper = SlotMapper::new(size.k(), direction);
        let (rows, instances) = if head.overlay.is_empty() {
            head.full_count_proc(&*head.h, &head.partitions, size, direction, &mapper)?
        } else {
            let view = OverlayView::new(&head.h, &head.overlay);
            let partitions = PartitionSet::build(&view, head.workers, head.max_units_per_item);
            head.full_count_proc(&view, &partitions, size, direction, &mapper)?
        };
        let mut maintained = head.maintained.as_ref().clone();
        maintained.push(MaintainedCounts::new(size, direction, rows, instances));
        faults::hit(faults::SITE_COMMIT, self.graph_id());
        let t_commit = Instant::now();
        self.cell.commit(head.next(None, None, None, Some(maintained)));
        trace::record_phase("commit", t_commit.elapsed().as_secs_f64());
        Ok(())
    }

    /// As [`Session::maintain`], validating the whole query: maintenance
    /// is Count-only and unscoped, so any other [`Output`] or [`Scope`]
    /// is rejected with the typed [`CountOnlyError`] (reachable through
    /// `anyhow::Error::downcast_ref`).
    pub fn maintain_query(&mut self, query: &MotifQuery) -> Result<()> {
        if !matches!(query.output, Output::Counts) {
            return Err(CountOnlyError::new(format!("`{}` output", query.output.label())).into());
        }
        if !query.scope.is_all() {
            return Err(CountOnlyError::new(format!("`{}` scope", query.scope.label())).into());
        }
        self.maintain(query.size, query.direction)
    }

    /// Apply a batch of edge insertions/deletions (original vertex ids)
    /// without reloading: patch the overlay, re-enumerate only the motif
    /// instances containing each changed edge, and fold the deltas into
    /// every maintained counter. Ops on self-loops, out-of-range vertices,
    /// already-present inserts and absent deletes are counted as skipped.
    /// Compaction (CSR rebuild + partition refresh) triggers at the end of
    /// a batch that pushed the overlay past `compact_ratio`.
    ///
    /// The whole batch is prepared **copy-on-write** — the overlay
    /// side-lists and maintained counters are cloned, the CSR/hub
    /// tier/ordering/partitions are not — and published as one new
    /// epoch at the end; concurrent readers keep answering from the
    /// pre-batch snapshot until the commit, and from their own pinned
    /// epoch after it.
    pub fn apply_edges(&mut self, deltas: &[EdgeDelta]) -> Result<DeltaReport> {
        let t0 = Instant::now();
        let head = self.cell.head();
        let mut report = DeltaReport::default();
        let mut touched: HashSet<u32> = HashSet::new();
        let n = head.n as u32;
        let mut overlay = head.overlay.as_ref().clone();
        let mut maintained = head.maintained.as_ref().clone();
        for d in deltas {
            if d.u == d.v || d.u >= n || d.v >= n {
                report.skipped_invalid += 1;
                continue;
            }
            let pu = head.ordering.new_of_old[d.u as usize];
            let pv = head.ordering.new_of_old[d.v as usize];
            let bits_pre = {
                let view = OverlayView::new(&head.h, &overlay);
                if head.directed {
                    (view.out_has_edge(pu, pv) as u8) | ((view.out_has_edge(pv, pu) as u8) << 1)
                } else if view.und_has_edge(pu, pv) {
                    0b11
                } else {
                    0
                }
            };
            match d.op {
                DeltaOp::Insert => {
                    if head.directed {
                        if bits_pre & 0b01 != 0 {
                            report.skipped_duplicate += 1;
                            continue;
                        }
                        // patch first: the union state (und pair present)
                        // is the post state for insertions
                        overlay.insert_directed(&head.h, pu, pv, bits_pre == 0);
                        let ch =
                            EdgeChange { u: pu, v: pv, bits_pre, bits_post: bits_pre | 0b01 };
                        reenumerate_into(
                            &head, &overlay, &ch, &mut maintained, &mut report, &mut touched,
                        );
                    } else {
                        if bits_pre != 0 {
                            report.skipped_duplicate += 1;
                            continue;
                        }
                        overlay.insert_undirected(&head.h, pu, pv);
                        let ch = EdgeChange { u: pu, v: pv, bits_pre: 0, bits_post: 0b11 };
                        reenumerate_into(
                            &head, &overlay, &ch, &mut maintained, &mut report, &mut touched,
                        );
                    }
                    report.inserted += 1;
                }
                DeltaOp::Delete => {
                    if head.directed {
                        if bits_pre & 0b01 == 0 {
                            report.skipped_missing += 1;
                            continue;
                        }
                        let bits_post = bits_pre & 0b10;
                        let ch = EdgeChange { u: pu, v: pv, bits_pre, bits_post };
                        if bits_post == 0 {
                            // the pair's last direction goes away: the pre
                            // state is the union state — enumerate, THEN patch
                            reenumerate_into(
                                &head, &overlay, &ch, &mut maintained, &mut report, &mut touched,
                            );
                            overlay.delete_directed(&head.h, pu, pv, true);
                        } else {
                            // reciprocal edge remains: und structure intact
                            overlay.delete_directed(&head.h, pu, pv, false);
                            reenumerate_into(
                                &head, &overlay, &ch, &mut maintained, &mut report, &mut touched,
                            );
                        }
                    } else {
                        if bits_pre == 0 {
                            report.skipped_missing += 1;
                            continue;
                        }
                        let ch = EdgeChange { u: pu, v: pv, bits_pre: 0b11, bits_post: 0 };
                        reenumerate_into(
                            &head, &overlay, &ch, &mut maintained, &mut report, &mut touched,
                        );
                        overlay.delete_undirected(&head.h, pu, pv);
                    }
                    report.deleted += 1;
                }
            }
        }

        // compaction folds the overlay into a rebuilt CSR; like every
        // other mutation it lands in the successor snapshot — readers
        // pinned to older epochs keep the pre-compaction CSR alive
        let mut new_h: Option<Arc<Graph>> = None;
        let mut new_partitions: Option<Arc<PartitionSet>> = None;
        if !overlay.is_empty() && overlay.ratio(&head.h) > self.compact_ratio {
            let mut rebuilt = overlay.compact(&head.h);
            if self.adjacency == AdjacencyMode::Hybrid {
                // the rebuilt CSR ships without bitmaps; re-tier it
                rebuilt.enable_hybrid(self.hub_threshold);
            }
            new_partitions = Some(Arc::new(PartitionSet::build(
                &rebuilt,
                head.workers,
                head.max_units_per_item,
            )));
            new_h = Some(Arc::new(rebuilt));
            overlay = DeltaOverlay::new();
            self.compactions += 1;
            report.compactions += 1;
        }
        report.touched_vertices = touched.len();
        report.overlay_entries = overlay.entries();
        report.overlay_ratio = overlay.ratio(new_h.as_deref().unwrap_or_else(|| head.h.as_ref()));
        if report.applied() > 0 || new_h.is_some() {
            // skipped-only batches change nothing: no commit, no epoch.
            // counters are only re-cloned when any exist; an empty list
            // keeps sharing the head's empty Arc
            let maintained = (!maintained.is_empty()).then_some(maintained);
            faults::hit(faults::SITE_COMMIT, self.graph_id());
            let t_commit = Instant::now();
            self.cell.commit(head.next(new_h, new_partitions, Some(overlay), maintained));
            trace::record_phase("commit", t_commit.elapsed().as_secs_f64());
        }
        report.elapsed_secs = t0.elapsed().as_secs_f64();
        trace::with_registry(|reg| {
            reg.counter("vdmc_engine_overlay_patches_total", "Overlay edge patches applied.")
                .add(report.applied() as u64);
            if report.compactions > 0 {
                reg.counter("vdmc_engine_compactions_total", "Overlay compactions committed.")
                    .add(report.compactions as u64);
            }
        });
        Ok(report)
    }
}

impl SessionSnapshot {
    /// Epoch stamp: 0 at load, +1 per committed write batch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Vertex count of the loaded graph.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Whether the loaded graph is directed.
    pub fn directed(&self) -> bool {
        self.directed
    }

    /// Worker threads (= shard count) queries run with.
    pub fn workers(&self) -> usize {
        self.partitions.n_shards()
    }

    /// Wall-clock seconds the one-time setup took.
    pub fn setup_secs(&self) -> f64 {
        self.setup_secs
    }

    /// Queries served so far (shared across epochs).
    pub fn queries_served(&self) -> usize {
        // relaxed: monitoring read of an independent counter.
        self.served.load(Ordering::Relaxed)
    }

    /// The cached partition set of this epoch.
    pub fn partitions(&self) -> &PartitionSet {
        &self.partitions
    }

    /// Pending overlay side-list entries frozen at this epoch.
    pub fn overlay_entries(&self) -> usize {
        self.overlay.entries()
    }

    /// Overlay occupancy relative to the base CSR.
    pub fn overlay_ratio(&self) -> f64 {
        self.overlay.ratio(self.h.as_ref())
    }

    /// Bytes held by the hybrid bitmap tier (0 under [`AdjacencyMode::Csr`]).
    pub fn tier_memory_bytes(&self) -> usize {
        self.h.tier_memory_bytes()
    }

    /// Bitmap hub rows of the relabeled undirected view.
    pub fn hub_rows(&self) -> usize {
        self.h.hub_rows()
    }

    /// The maintained counters frozen at this epoch.
    pub fn maintained(&self) -> &[MaintainedCounts] {
        &self.maintained
    }

    /// Resident bytes of this snapshot: CSR views + hub tier, overlay,
    /// partitions, maintained counters, ordering.
    pub fn memory_bytes(&self) -> usize {
        self.h.memory_bytes()
            + self.overlay.memory_bytes()
            + self.partitions.memory_bytes()
            + self.maintained.iter().map(|m| m.memory_bytes()).sum::<usize>()
            + self.ordering.memory_bytes()
    }

    /// Bytes this snapshot holds that `head` does not share — what a
    /// pinned superseded epoch costs on top of the head.
    fn retained_vs(&self, head: &SessionSnapshot) -> usize {
        let mut bytes = 0;
        if !Arc::ptr_eq(&self.h, &head.h) {
            bytes += self.h.memory_bytes();
        }
        if !Arc::ptr_eq(&self.partitions, &head.partitions) {
            bytes += self.partitions.memory_bytes();
        }
        if !Arc::ptr_eq(&self.overlay, &head.overlay) {
            bytes += self.overlay.memory_bytes();
        }
        if !Arc::ptr_eq(&self.maintained, &head.maintained) {
            bytes += self.maintained.iter().map(|m| m.memory_bytes()).sum::<usize>();
        }
        bytes
    }

    /// Build the successor snapshot: epoch + 1, replacing only the given
    /// components; everything else is shared by `Arc` clone.
    fn next(
        &self,
        h: Option<Arc<Graph>>,
        partitions: Option<Arc<PartitionSet>>,
        overlay: Option<DeltaOverlay>,
        maintained: Option<Vec<MaintainedCounts>>,
    ) -> Arc<SessionSnapshot> {
        Arc::new(SessionSnapshot {
            directed: self.directed,
            n: self.n,
            epoch: self.epoch + 1,
            ordering: self.ordering.clone(),
            h: h.unwrap_or_else(|| self.h.clone()),
            partitions: partitions.unwrap_or_else(|| self.partitions.clone()),
            overlay: overlay.map(Arc::new).unwrap_or_else(|| self.overlay.clone()),
            maintained: maintained.map(Arc::new).unwrap_or_else(|| self.maintained.clone()),
            workers: self.workers,
            max_units_per_item: self.max_units_per_item,
            setup_secs: self.setup_secs,
            served: self.served.clone(),
        })
    }

    // ------------------------------------------------------------- queries

    /// Run one query — any [`Output`], any [`Scope`].
    pub fn query(&self, query: &MotifQuery) -> Result<QueryOutput> {
        Ok(self.query_with_report(query)?.0)
    }

    /// As [`Session::query`], also returning the run report. The report's
    /// `setup_secs`/`setup_reused` show whether this call paid for setup
    /// (first query) or served from cache; `per_class_totals` carries the
    /// exact class histogram for every output kind. While deltas are
    /// pending the enumeration runs over the overlay view with a freshly
    /// budgeted partition (the cached one has stale unit counts).
    pub fn query_with_report(&self, query: &MotifQuery) -> Result<(QueryOutput, RunReport)> {
        self.query_with_report_cancel(query, None)
    }

    /// As [`SessionSnapshot::query_with_report`], polling `cancel` once
    /// per work unit: a cancelled or deadline-blown run stops within one
    /// unit and fails with the typed [`QueryAborted`] (partial progress
    /// in `units_done`/`units_total`) instead of returning counts. A
    /// snapshot is immutable, so an aborted query leaves no trace —
    /// epochs, pool state and maintained counters are untouched.
    pub fn query_with_report_cancel(
        &self,
        query: &MotifQuery,
        cancel: Option<&CancelToken>,
    ) -> Result<(QueryOutput, RunReport)> {
        if query.direction == Direction::Directed && !self.directed {
            bail!("directed motif counting requested on an undirected graph");
        }
        if let Some(reason) = cancel.and_then(CancelToken::check) {
            // already dead on arrival (deadline spent in the queue, or
            // the client vanished): don't start the enumeration at all
            record_abort(reason);
            return Err(QueryAborted { reason, units_done: 0, units_total: 0 }.into());
        }
        // relaxed: served is a pure tally — exact under the RMW total
        // order, publishing nothing else.
        let reused = self.served.fetch_add(1, Ordering::Relaxed) > 0;
        let start = Instant::now();
        let mapper = SlotMapper::new(query.size.k(), query.direction);

        let mut setup_phase = 0.0;
        let (mut out, metrics, queue_items, queue_units, phases) = if self.overlay.is_empty() {
            self.query_on(&*self.h, &self.partitions, query, &mapper, cancel)?
        } else {
            let t_setup = Instant::now();
            let view = OverlayView::new(&self.h, &self.overlay);
            let partitions = PartitionSet::build(&view, self.workers, self.max_units_per_item);
            setup_phase = t_setup.elapsed().as_secs_f64();
            trace::record_phase("setup", setup_phase);
            self.query_on(&view, &partitions, query, &mapper, cancel)?
        };
        let elapsed = start.elapsed().as_secs_f64();
        if let QueryOutput::Counts(c) = &mut out {
            c.elapsed_secs = elapsed;
        }

        let mut per_class_totals = vec![0u64; mapper.n_classes()];
        for w in &metrics {
            for (t, c) in per_class_totals.iter_mut().zip(&w.per_class) {
                *t += c;
            }
        }
        let total_instances: u64 = metrics.iter().map(|w| w.instances).sum();
        let report = RunReport {
            workers: metrics,
            total_instances,
            elapsed_secs: elapsed,
            queue_items,
            queue_units,
            setup_secs: if reused { 0.0 } else { self.setup_secs },
            setup_reused: reused,
            phase_secs: PhaseSecs { setup: setup_phase, ..phases },
            tier_memory_bytes: self.h.tier_memory_bytes(),
            per_class_totals,
        };
        let class_ids = mapper.class_ids();
        let k_str = query.size.k().to_string();
        trace::with_registry(|reg| {
            reg.counter("vdmc_engine_units_total", "Work units scheduled by queries.")
                .add(report.queue_units as u64);
            reg.counter("vdmc_engine_items_total", "Work items scheduled by queries.")
                .add(report.queue_items as u64);
            reg.counter("vdmc_engine_steals_total", "Work items claimed by stealing.")
                .add(report.total_steals());
            for (slot, &total) in report.per_class_totals.iter().enumerate() {
                if total > 0 {
                    let class = class_ids[slot].to_string();
                    reg.counter_with(
                        "vdmc_engine_instances_total",
                        "Motif instances emitted, by motif size and class id.",
                        &[("k", &k_str), ("class", &class)],
                    )
                    .add(total);
                }
            }
        });
        Ok((out, report))
    }

    /// Count all k-motifs per vertex — the [`Output::Counts`] shorthand.
    pub fn count(&self, query: &MotifQuery) -> Result<MotifCounts> {
        Ok(self.count_with_report(query)?.0)
    }

    /// As [`Session::count`], also returning the run report. Rejects
    /// queries whose output is not [`Output::Counts`]; use
    /// [`Session::query`] for the other output kinds.
    pub fn count_with_report(&self, query: &MotifQuery) -> Result<(MotifCounts, RunReport)> {
        self.count_with_report_cancel(query, None)
    }

    /// As [`SessionSnapshot::count_with_report`] with cooperative
    /// cancellation — see [`SessionSnapshot::query_with_report_cancel`].
    pub fn count_with_report_cancel(
        &self,
        query: &MotifQuery,
        cancel: Option<&CancelToken>,
    ) -> Result<(MotifCounts, RunReport)> {
        if !matches!(query.output, Output::Counts) {
            bail!(
                "Session::count serves the counts output only (query asked for {}); \
                 use Session::query",
                query.output.label()
            );
        }
        let (out, report) = self.query_with_report_cancel(query, cancel)?;
        match out {
            QueryOutput::Counts(c) => Ok((c, report)),
            _ => unreachable!("counts output produced a non-counts result"),
        }
    }

    /// Run one query over any probe surface (the cached CSR or the
    /// overlay view), producing the final (original-id) result plus the
    /// per-worker metrics, queue statistics and the enumerate/merge
    /// phase timings (`PhaseSecs::setup` is stamped by the caller).
    fn query_on<G: GraphProbe + Sync>(
        &self,
        h: &G,
        partitions: &PartitionSet,
        query: &MotifQuery,
        mapper: &SlotMapper,
        cancel: Option<&CancelToken>,
    ) -> Result<(QueryOutput, Vec<WorkerMetrics>, usize, usize, PhaseSecs)> {
        let k = query.size.k();
        let n_classes = mapper.n_classes();
        // the builder validates these; struct-literal queries get the
        // same errors here instead of a panic deeper in the sink layer
        match query.output {
            Output::Instances { limit: 0 } => bail!("instances output needs a limit >= 1"),
            Output::Sample { per_class: 0, .. } => bail!("sample output needs per_class >= 1"),
            Output::TopVertices { k: 0 } => bail!("top-vertices output needs k >= 1"),
            _ => {}
        }
        let scope = self.resolve_scope(h, &query.scope, k)?;
        let out = match query.output {
            Output::Counts => {
                let ranges = partitions.ranges();
                let sink = CountEnumSink::new(query.sink, self.n, n_classes, &ranges);
                let t_run = Instant::now();
                let (metrics, qi, qu) =
                    run_enum(h, partitions, query, mapper, &sink, scope.as_ref(), cancel)?;
                let enumerate = t_run.elapsed().as_secs_f64();
                let t_merge = Instant::now();
                let (mut rows, instances) = sink.finish();
                if let Some(sc) = &scope {
                    // out-of-scope rows hold partial counts (only their
                    // instances shared with the scope); zero them so the
                    // result never exposes a partial row
                    zero_non_members(&mut rows, n_classes, &sc.members);
                }
                let per_vertex = self.ordering.unapply_rows(&rows, n_classes);
                // exact per-class instance totals from the worker
                // metrics: the only correct class histogram under a
                // scope, where column sums don't divide by k
                let mut per_class_instances = vec![0u64; n_classes];
                for w in &metrics {
                    for (t, c) in per_class_instances.iter_mut().zip(&w.per_class) {
                        *t += c;
                    }
                }
                let counts = MotifCounts {
                    k,
                    direction: query.direction,
                    n: self.n,
                    n_classes,
                    per_vertex,
                    class_ids: mapper.class_ids(),
                    per_class_instances,
                    total_instances: instances,
                    elapsed_secs: 0.0, // stamped by query_with_report
                };
                (QueryOutput::Counts(counts), metrics, qi, qu, close_phases(enumerate, t_merge))
            }
            Output::Instances { limit } => {
                let sink = InstanceEnumSink::new(limit, n_classes);
                let t_run = Instant::now();
                let (metrics, qi, qu) =
                    run_enum(h, partitions, query, mapper, &sink, scope.as_ref(), cancel)?;
                let enumerate = t_run.elapsed().as_secs_f64();
                let t_merge = Instant::now();
                let raw = sink.finish();
                let mut instances: Vec<MotifInstance> =
                    raw.recs.iter().map(|r| self.instance_of(r, k)).collect();
                instances.sort_unstable_by(|a, b| {
                    a.verts.cmp(&b.verts).then(a.class_slot.cmp(&b.class_slot))
                });
                let list = InstanceList {
                    k,
                    direction: query.direction,
                    class_ids: mapper.class_ids(),
                    instances,
                    truncated: raw.truncated,
                    total_seen: raw.total_seen,
                    per_class_seen: raw.per_class_seen,
                };
                (QueryOutput::Instances(list), metrics, qi, qu, close_phases(enumerate, t_merge))
            }
            Output::Sample { per_class, seed } => {
                let sink = SampleEnumSink::new(per_class, seed, n_classes);
                let t_run = Instant::now();
                let (metrics, qi, qu) =
                    run_enum(h, partitions, query, mapper, &sink, scope.as_ref(), cancel)?;
                let enumerate = t_run.elapsed().as_secs_f64();
                let t_merge = Instant::now();
                let raw = sink.finish();
                let class_ids = mapper.class_ids();
                let classes: Vec<ClassSample> = raw
                    .per_class
                    .into_iter()
                    .enumerate()
                    .map(|(slot, (seen, recs))| ClassSample {
                        slot: slot as u16,
                        class_id: class_ids[slot],
                        seen,
                        instances: recs.iter().map(|r| self.instance_of(r, k)).collect(),
                    })
                    .collect();
                let sample = SampleSummary {
                    k,
                    direction: query.direction,
                    per_class,
                    seed,
                    classes,
                    total_seen: raw.total_seen,
                };
                (QueryOutput::Sample(sample), metrics, qi, qu, close_phases(enumerate, t_merge))
            }
            Output::TopVertices { k: top_k } => {
                let sink = TopVerticesEnumSink::new(self.n, n_classes);
                let t_run = Instant::now();
                let (metrics, qi, qu) =
                    run_enum(h, partitions, query, mapper, &sink, scope.as_ref(), cancel)?;
                let enumerate = t_run.elapsed().as_secs_f64();
                let t_merge = Instant::now();
                let (mut rows, instances) = sink.finish();
                if let Some(sc) = &scope {
                    zero_non_members(&mut rows, n_classes, &sc.members);
                }
                let per_vertex = self.ordering.unapply_rows(&rows, n_classes);
                let mut per_class: Vec<Vec<(u32, u64)>> = Vec::with_capacity(n_classes);
                for slot in 0..n_classes {
                    let mut ranked: Vec<(u32, u64)> = (0..self.n as u32)
                        .filter_map(|v| {
                            let c = per_vertex[v as usize * n_classes + slot];
                            (c > 0).then_some((v, c))
                        })
                        .collect();
                    ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                    ranked.truncate(top_k);
                    per_class.push(ranked);
                }
                let top = TopVertices {
                    k,
                    direction: query.direction,
                    class_ids: mapper.class_ids(),
                    top_k,
                    per_class,
                    total_instances: instances,
                };
                (QueryOutput::TopVertices(top), metrics, qi, qu, close_phases(enumerate, t_merge))
            }
        };
        let (out, metrics, qi, qu, phases) = out;
        Ok((out, metrics, qi, qu, phases))
    }

    /// Map one buffered instance record to original ids, members sorted.
    fn instance_of(&self, rec: &InstanceRec, k: usize) -> MotifInstance {
        let mut verts: Vec<u32> = rec.verts[..k]
            .iter()
            .map(|&pv| self.ordering.old_of_new[pv as usize])
            .collect();
        verts.sort_unstable();
        MotifInstance { verts, class_slot: rec.class_slot }
    }

    /// Resolve a query scope against the run surface: member bits plus
    /// the (k-1)-hop root ball, both in processing ids.
    fn resolve_scope<G: GraphProbe>(
        &self,
        h: &G,
        scope: &Scope,
        k: usize,
    ) -> Result<Option<ScopeSets>> {
        let to_bits = |vs: &[u32]| -> Result<VertexBits> {
            let mut bits = VertexBits::new(self.n);
            for &v in vs {
                if v as usize >= self.n {
                    bail!("scope vertex {v} out of range (n={})", self.n);
                }
                bits.insert(self.ordering.new_of_old[v as usize]);
            }
            Ok(bits)
        };
        match scope {
            Scope::All => Ok(None),
            Scope::Vertices(vs) => {
                if vs.is_empty() {
                    bail!("vertex scope needs at least one vertex");
                }
                let members = to_bits(vs)?;
                let roots = expand_hops(h, &members, k - 1);
                Ok(Some(ScopeSets { members, roots }))
            }
            Scope::Neighborhood { seeds, radius } => {
                if seeds.is_empty() {
                    bail!("neighborhood scope needs at least one seed");
                }
                let members = expand_hops(h, &to_bits(seeds)?, *radius);
                let roots = expand_hops(h, &members, k - 1);
                Ok(Some(ScopeSets { members, roots }))
            }
        }
    }

    /// The closed `radius`-hop undirected neighborhood of `seeds`, in
    /// ORIGINAL vertex ids (sorted). Runs over the overlay view while
    /// deltas are pending — the service's scoped `vertex_counts` resolves
    /// its row set through this.
    pub fn neighborhood(&self, seeds: &[u32], radius: usize) -> Result<Vec<u32>> {
        let scope = Scope::Neighborhood { seeds: seeds.to_vec(), radius };
        let resolved = if self.overlay.is_empty() {
            self.resolve_scope(&*self.h, &scope, 1)?
        } else {
            let view = OverlayView::new(&self.h, &self.overlay);
            self.resolve_scope(&view, &scope, 1)?
        };
        // only Scope::Full resolves to None, and we built a Neighborhood
        let Some(sets) = resolved else {
            bail!("internal: neighborhood scope resolved to no member set");
        };
        let mut out: Vec<u32> =
            sets.members.iter().map(|pv| self.ordering.old_of_new[pv as usize]).collect();
        out.sort_unstable();
        Ok(out)
    }

    // ------------------------------------------------- streaming support

    /// One full, unscoped count in processing-id rows — the baseline a
    /// maintained counter starts from.
    fn full_count_proc<G: GraphProbe + Sync>(
        &self,
        h: &G,
        partitions: &PartitionSet,
        size: MotifSize,
        direction: Direction,
        mapper: &SlotMapper,
    ) -> Result<(Vec<u64>, u64)> {
        let query = MotifQuery { size, direction, ..Default::default() };
        let sink =
            CountEnumSink::new(query.sink, self.n, mapper.n_classes(), &partitions.ranges());
        run_enum(h, partitions, &query, mapper, &sink, None, None)?;
        Ok(sink.finish())
    }

    /// Read a maintained counter back as [`MotifCounts`] (original vertex
    /// ids). `None` when (size, direction) was never [`Session::maintain`]ed.
    /// This materializes all n × classes rows; point lookups should use
    /// [`Session::maintained_vertex`] instead.
    pub fn maintained_counts(&self, size: MotifSize, direction: Direction) -> Option<MotifCounts> {
        let m = self.maintained.iter().find(|m| m.size() == size && m.direction() == direction)?;
        let rows = self.ordering.unapply_rows(m.per_vertex(), m.n_classes());
        Some(m.to_counts(self.n, rows, 0.0))
    }

    /// One maintained counter row for one ORIGINAL vertex id — the
    /// O(classes) lookup the service's `VertexCounts` request serves
    /// from, with no n-sized materialization. `None` when (size,
    /// direction) is not maintained or `v` is out of range.
    pub fn maintained_vertex(
        &self,
        size: MotifSize,
        direction: Direction,
        v: u32,
    ) -> Option<&[u64]> {
        let m = self.maintained.iter().find(|m| m.size() == size && m.direction() == direction)?;
        if v as usize >= self.n {
            return None;
        }
        let pv = self.ordering.new_of_old[v as usize] as usize;
        let nc = m.n_classes();
        Some(&m.per_vertex()[pv * nc..(pv + 1) * nc])
    }

    /// Materialize the session's current graph (base + overlay) back into
    /// ORIGINAL vertex ids — the reload-and-recount oracle used by tests
    /// and `vdmc stream --verify`.
    pub fn snapshot_graph(&self) -> Graph {
        let proc = self.overlay.materialize(&self.h);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        if self.directed {
            for (u, v) in proc.out.edges() {
                edges.push((
                    self.ordering.old_of_new[u as usize],
                    self.ordering.old_of_new[v as usize],
                ));
            }
        } else {
            for (u, v) in proc.und.edges() {
                if u < v {
                    edges.push((
                        self.ordering.old_of_new[u as usize],
                        self.ordering.old_of_new[v as usize],
                    ));
                }
            }
        }
        Graph::from_edges(self.n, &edges, self.directed)
    }
}

fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Re-enumerate the motif instances containing one changed edge and fold
/// the deltas into `maintained` — the copy-on-write working set of an
/// in-flight `apply_edges` batch (`head` supplies the shared base CSR and
/// run parameters; `overlay` is the batch-local patched state).
fn reenumerate_into(
    head: &SessionSnapshot,
    overlay: &DeltaOverlay,
    ch: &EdgeChange,
    maintained: &mut [MaintainedCounts],
    report: &mut DeltaReport,
    touched: &mut HashSet<u32>,
) {
    if maintained.is_empty() {
        return;
    }
    let view = OverlayView::new(&head.h, overlay);
    let stats = reenumerate_edge(
        &view,
        head.directed,
        ch,
        maintained,
        head.workers,
        head.max_units_per_item,
        touched,
    );
    report.reenumerated_units += stats.units;
    report.reenumerated_sets += stats.sets;
}

/// Zero the rows of vertices outside the scope member set (processing-id
/// rows) so a scoped result never exposes a partial out-of-scope row.
fn zero_non_members(rows: &mut [u64], n_classes: usize, members: &VertexBits) {
    for (v, row) in rows.chunks_mut(n_classes).enumerate() {
        if !members.contains(v as u32) {
            row.iter_mut().for_each(|x| *x = 0);
        }
    }
}

/// Grow `start` by `hops` undirected BFS layers over any probe surface.
fn expand_hops<G: GraphProbe>(h: &G, start: &VertexBits, hops: usize) -> VertexBits {
    let mut out = start.clone();
    if hops == 0 {
        return out;
    }
    let mut frontier: Vec<u32> = start.iter().collect();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &v in &frontier {
            for w in h.und_neighbors(v) {
                if out.insert(w) {
                    next.push(w);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        frontier = next;
    }
    out
}

/// Close the enumerate/merge bookkeeping of one `query_on` arm: record
/// the enumerate span on the active trace (the sinks record their own
/// `merge` span inside `finish`) and return the report's phase
/// breakdown, whose `merge` covers sink merge *plus* result assembly.
fn close_phases(enumerate: f64, merge_started: Instant) -> PhaseSecs {
    trace::record_phase("enumerate", enumerate);
    PhaseSecs { setup: 0.0, enumerate, merge: merge_started.elapsed().as_secs_f64() }
}

/// Record one abort on the active trace's registry: deadline blows get
/// their own counter, explicit cancellations are labeled by reason.
fn record_abort(reason: AbortReason) {
    trace::with_registry(|reg| match reason {
        AbortReason::Deadline => {
            reg.counter(DEADLINE_EXCEEDED_TOTAL, HELP_DEADLINE_EXCEEDED).inc();
        }
        _ => {
            reg.counter_with(CANCELLED_TOTAL, HELP_CANCELLED, &[("reason", reason.label())])
                .inc();
        }
    });
}

/// Best-effort text of a caught panic payload.
fn panic_note(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Drive one query's enumeration into any [`EnumSink`]: build the
/// scheduler (scope-filtering the cached items at the work-unit level),
/// run one monomorphized worker loop per thread, and return the metrics
/// plus the (filtered) queue statistics.
///
/// Failure containment happens here. Each worker polls `cancel` once
/// per work unit and quiesces within one unit of a cancel/deadline —
/// the run then fails with the typed [`QueryAborted`] carrying exact
/// units-done/units-total progress. Each worker closure also runs under
/// `catch_unwind`: a panicking worker latches the shared stop flag (its
/// siblings bail at their next unit), is counted in
/// `vdmc_panics_caught_total`, and surfaces as an error — never a
/// process death, and never a partial result presented as complete.
fn run_enum<G: GraphProbe + Sync, S: EnumSink>(
    h: &G,
    partitions: &PartitionSet,
    query: &MotifQuery,
    mapper: &SlotMapper,
    sink: &S,
    scope: Option<&ScopeSets>,
    cancel: Option<&CancelToken>,
) -> Result<(Vec<WorkerMetrics>, usize, usize)> {
    let workers = partitions.n_shards();
    let (scheduler, queue_items, queue_units): (Box<dyn Scheduler>, usize, usize) = match scope {
        None => {
            let s: Box<dyn Scheduler> = match query.scheduler {
                SchedulerMode::SharedCursor => {
                    Box::new(SharedCursorScheduler::new(partitions.all_items()))
                }
                SchedulerMode::WorkStealing => {
                    Box::new(WorkStealingScheduler::new(partitions.item_lists()))
                }
                SchedulerMode::WorkStealingBatch => {
                    Box::new(WorkStealingScheduler::half_deque(partitions.item_lists()))
                }
            };
            (s, partitions.total_items, partitions.total_units)
        }
        Some(sc) => {
            // the scope's speedup lives here: only units whose root can
            // own an in-scope instance ever reach a worker
            let keep = |it: &WorkItem| sc.roots.contains(it.root);
            match query.scheduler {
                SchedulerMode::SharedCursor => {
                    let items: Vec<WorkItem> =
                        partitions.all_items().into_iter().filter(keep).collect();
                    let (qi, qu) = (items.len(), total_units(&items));
                    (Box::new(SharedCursorScheduler::new(items)), qi, qu)
                }
                SchedulerMode::WorkStealing | SchedulerMode::WorkStealingBatch => {
                    let lists: Vec<Vec<WorkItem>> = partitions
                        .item_lists()
                        .into_iter()
                        .map(|l| l.into_iter().filter(keep).collect())
                        .collect();
                    let qi = lists.iter().map(Vec::len).sum();
                    let qu = lists.iter().map(|l| total_units(l)).sum();
                    let s: Box<dyn Scheduler> =
                        if query.scheduler == SchedulerMode::WorkStealingBatch {
                            Box::new(WorkStealingScheduler::half_deque(lists))
                        } else {
                            Box::new(WorkStealingScheduler::new(lists))
                        };
                    (s, qi, qu)
                }
            }
        }
    };

    let sched_ref: &dyn Scheduler = scheduler.as_ref();
    let members = scope.map(|sc| &sc.members);
    let size = query.size;
    let dir = query.direction;
    // shared early-stop latch: the first worker to observe a cancel (or
    // to panic) flips it, and every sibling bails at its next unit
    let stop = AtomicBool::new(false);
    let stop_ref = &stop;
    let mut metrics: Vec<WorkerMetrics> = Vec::with_capacity(workers);
    let mut abort: Option<AbortReason> = None;
    let mut panics = 0u64;
    let mut note = String::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                s.spawn(move || {
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        worker_loop(
                            h, size, dir, mapper, sched_ref, sink, members, w, cancel, stop_ref,
                        )
                    }));
                    if out.is_err() {
                        // relaxed: stop is a pure quiesce hint — the
                        // panic payload travels through the join result,
                        // so the flag publishes no data.
                        stop_ref.store(true, Ordering::Relaxed);
                    }
                    out
                })
            })
            .collect();
        for t in handles {
            // a join error is a panic that escaped catch_unwind (can only
            // be the store above) — fold it into the caught-panic path
            match t.join().unwrap_or_else(Err) {
                Ok((m, a)) => {
                    if abort.is_none() {
                        abort = a;
                    }
                    metrics.push(m);
                }
                Err(payload) => {
                    panics += 1;
                    note = panic_note(payload.as_ref());
                }
            }
        }
    });
    if panics > 0 {
        trace::with_registry(|reg| {
            reg.counter(PANICS_CAUGHT_TOTAL, HELP_PANICS_CAUGHT).add(panics);
        });
        bail!("{panics} enumeration worker(s) panicked (caught): {note}");
    }
    if let Some(reason) = abort {
        let units_done: u64 = metrics.iter().map(|m| m.units).sum();
        record_abort(reason);
        return Err(QueryAborted { reason, units_done, units_total: queue_units as u64 }.into());
    }
    Ok((metrics, queue_items, queue_units))
}

/// Worker inner loop shared by every scheduler × sink combination and
/// every probe surface (static CSR or delta overlay): claim items until
/// drained, feed every enumerated instance to the sink handle. The handle
/// type is monomorphized, and the scope test compiles away entirely on
/// unscoped runs (const-generic split in [`drive`]) — the Count fast path
/// is the pre-redesign `record(verts, slot)` call, nothing more.
#[allow(clippy::too_many_arguments)]
fn worker_loop<G: GraphProbe, S: EnumSink>(
    h: &G,
    size: MotifSize,
    dir: Direction,
    mapper: &SlotMapper,
    sched: &dyn Scheduler,
    sink: &S,
    members: Option<&VertexBits>,
    worker_id: usize,
    cancel: Option<&CancelToken>,
    stop: &AtomicBool,
) -> (WorkerMetrics, Option<AbortReason>) {
    let mut m = WorkerMetrics {
        worker_id,
        per_class: vec![0; mapper.n_classes()],
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut handle = sink.attach(worker_id);
    let mut ctx = bfs3::EnumCtx::new(h.n());
    let aborted = match members {
        None => {
            let empty = VertexBits::default();
            drive::<_, _, false>(
                h, size, dir, mapper, sched, &empty, &mut handle, &mut ctx, &mut m, worker_id,
                cancel, stop,
            )
        }
        Some(bits) => drive::<_, _, true>(
            h, size, dir, mapper, sched, bits, &mut handle, &mut ctx, &mut m, worker_id, cancel,
            stop,
        ),
    };
    handle.flush();
    m.busy_secs = t0.elapsed().as_secs_f64();
    (m, aborted)
}

/// The per-worker claim loop. Cancellation is polled here, **once per
/// work unit** (`WorkItem`s batch up to `max_units_per_item` units, so
/// a per-claim check alone could overshoot by a whole item): one
/// relaxed load of the shared stop latch, one token check, and — in
/// chaos/debug builds only — the `enumerate_unit` fault site. Returns
/// the abort reason if this worker was the one that observed the
/// cancellation (`None` both on a drained queue and when only the stop
/// latch was seen — the observing sibling reports the reason).
#[allow(clippy::too_many_arguments)]
fn drive<G: GraphProbe, H: EmitHandle, const SCOPED: bool>(
    h: &G,
    size: MotifSize,
    dir: Direction,
    mapper: &SlotMapper,
    sched: &dyn Scheduler,
    members: &VertexBits,
    handle: &mut H,
    ctx: &mut bfs3::EnumCtx,
    m: &mut WorkerMetrics,
    worker_id: usize,
    cancel: Option<&CancelToken>,
    stop: &AtomicBool,
) -> Option<AbortReason> {
    while let Some(claim) = sched.pop(worker_id) {
        let item = claim.item;
        m.items += 1;
        if claim.stolen {
            m.steals += 1;
            m.steal_batch += claim.batch as u64;
        }
        for j in item.j_start..item.j_end {
            // relaxed: quiesce hint only — abort data flows via each
            // worker's return value through the join, and a stale read
            // costs at most one extra work unit.
            if stop.load(Ordering::Relaxed) {
                return None;
            }
            if let Some(c) = cancel {
                if let Some(reason) = c.check() {
                    // relaxed: same quiesce hint as above.
                    stop.store(true, Ordering::Relaxed);
                    return Some(reason);
                }
            }
            faults::hit(faults::SITE_ENUMERATE_UNIT, cancel.and_then(CancelToken::tag));
            match size {
                MotifSize::Three => {
                    bfs3::enumerate_unit(h, dir, item.root, j as usize, ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        if SCOPED && !members.contains_any(verts) {
                            return;
                        }
                        m.instances += 1;
                        m.per_class[slot as usize] += 1;
                        handle.emit(MotifEvent { verts, class_slot: slot });
                    });
                }
                MotifSize::Four => {
                    bfs4::enumerate_unit(h, dir, item.root, j as usize, ctx, &mut |verts, raw| {
                        let slot = mapper.slot(raw);
                        debug_assert_ne!(slot, NO_SLOT, "enumerator produced invalid id {raw}");
                        if SCOPED && !members.contains_any(verts) {
                            return;
                        }
                        m.instances += 1;
                        m.per_class[slot as usize] += 1;
                        handle.emit(MotifEvent { verts, class_slot: slot });
                    });
                }
            }
            m.units += 1;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{count_motifs, CountConfig};
    use crate::graph::generators;
    use crate::motifs::counter::CounterMode;

    #[test]
    fn session_reuse_skips_setup_and_matches_seed_path() {
        let g = generators::gnp_directed(80, 0.08, 41);
        let session = Session::load(&g);
        assert_eq!(session.queries_served(), 0);

        let q3 = MotifQuery { size: MotifSize::Three, ..Default::default() };
        let (c1, r1) = session.count_with_report(&q3).unwrap();
        assert!(!r1.setup_reused);
        let (c2, r2) = session.count_with_report(&q3).unwrap();
        assert!(r2.setup_reused, "second query must reuse cached setup");
        assert_eq!(r2.setup_secs, 0.0);
        assert_eq!(session.queries_served(), 2);

        // identical to two independent seed-path calls
        let cfg = CountConfig { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() };
        let seed1 = count_motifs(&g, &cfg).unwrap();
        let seed2 = count_motifs(&g, &cfg).unwrap();
        assert_eq!(c1.per_vertex, seed1.per_vertex);
        assert_eq!(c2.per_vertex, seed2.per_vertex);
        assert_eq!(c1.total_instances, seed1.total_instances);
    }

    #[test]
    fn one_session_serves_mixed_queries() {
        let g = generators::gnp_directed(60, 0.1, 5);
        let session = Session::load(&g);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let got = session
                    .count(&MotifQuery { size, direction: dir, ..Default::default() })
                    .unwrap();
                let want = count_motifs(
                    &g,
                    &CountConfig { size, direction: dir, ..Default::default() },
                )
                .unwrap();
                assert_eq!(got.per_vertex, want.per_vertex, "{size:?} {dir:?}");
            }
        }
        assert_eq!(session.queries_served(), 4);
    }

    #[test]
    fn every_scheduler_sink_combination_agrees() {
        let g = generators::barabasi_albert(150, 4, 3);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let base = session
            .count(&MotifQuery {
                size: MotifSize::Four,
                direction: Direction::Undirected,
                scheduler: SchedulerMode::SharedCursor,
                sink: CounterMode::Atomic,
                ..Default::default()
            })
            .unwrap();
        for scheduler in [
            SchedulerMode::SharedCursor,
            SchedulerMode::WorkStealing,
            SchedulerMode::WorkStealingBatch,
        ] {
            for sink in [CounterMode::Atomic, CounterMode::Sharded, CounterMode::PartitionLocal] {
                let got = session
                    .count(&MotifQuery {
                        size: MotifSize::Four,
                        direction: Direction::Undirected,
                        scheduler,
                        sink,
                        ..Default::default()
                    })
                    .unwrap();
                assert_eq!(got.per_vertex, base.per_vertex, "{scheduler:?} {sink:?}");
                assert_eq!(got.total_instances, base.total_instances, "{scheduler:?} {sink:?}");
            }
        }
    }

    #[test]
    fn directed_query_on_undirected_session_is_error() {
        let g = generators::star(6);
        let session = Session::load(&g);
        let err = session.count(&MotifQuery::default()).unwrap_err();
        assert!(err.to_string().contains("undirected"));
        let mut session = session;
        let err = session.maintain(MotifSize::Three, Direction::Directed).unwrap_err();
        assert!(err.to_string().contains("undirected"));
    }

    #[test]
    fn report_units_cover_graph_for_all_schedulers() {
        let g = generators::barabasi_albert(300, 3, 17);
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for scheduler in [
            SchedulerMode::SharedCursor,
            SchedulerMode::WorkStealing,
            SchedulerMode::WorkStealingBatch,
        ] {
            let (counts, report) = session
                .count_with_report(&MotifQuery {
                    size: MotifSize::Three,
                    direction: Direction::Undirected,
                    scheduler,
                    ..Default::default()
                })
                .unwrap();
            let worker_units: u64 = report.workers.iter().map(|w| w.units).sum();
            assert_eq!(worker_units as usize, report.queue_units);
            assert_eq!(report.queue_units, g.und.m() / 2);
            let worker_instances: u64 = report.workers.iter().map(|w| w.instances).sum();
            assert_eq!(worker_instances, report.total_instances);
            // the class histogram is exact and consistent both ways
            assert_eq!(report.per_class_totals.iter().sum::<u64>(), report.total_instances);
            assert_eq!(report.per_class_totals, counts.class_instances());
        }
    }

    #[test]
    fn batch_stealing_records_batch_mass() {
        // star graph: all units on the hub shard, every other worker steals
        let g = generators::star(600);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let (_, report) = session
            .count_with_report(&MotifQuery {
                size: MotifSize::Three,
                direction: Direction::Undirected,
                scheduler: SchedulerMode::WorkStealingBatch,
                ..Default::default()
            })
            .unwrap();
        // steal-batch mass >= steal count whenever any steal happened
        assert!(report.total_steal_batch() >= report.total_steals());
    }

    // --------------------------------------------------- outputs & scopes

    #[test]
    fn count_rejects_non_count_outputs() {
        let g = generators::star(6);
        let session = Session::load(&g);
        let q = MotifQuery {
            direction: Direction::Undirected,
            output: Output::Instances { limit: 10 },
            ..Default::default()
        };
        let err = session.count(&q).unwrap_err();
        assert!(err.to_string().contains("counts output only"), "{err}");
    }

    #[test]
    fn instances_match_counts_histogram() {
        let g = generators::gnp_directed(40, 0.12, 9);
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for size in [MotifSize::Three, MotifSize::Four] {
            let base = MotifQuery { size, direction: Direction::Directed, ..Default::default() };
            let counts = session.count(&base).unwrap();
            let q = MotifQuery { output: Output::Instances { limit: usize::MAX >> 1 }, ..base };
            let (out, report) = session.query_with_report(&q).unwrap();
            let list = match out {
                QueryOutput::Instances(l) => l,
                other => panic!("{other:?}"),
            };
            assert!(!list.truncated);
            assert_eq!(list.total_seen, counts.total_instances);
            assert_eq!(list.instances.len() as u64, counts.total_instances);
            assert_eq!(list.per_class_seen, counts.class_instances());
            assert_eq!(report.per_class_totals, counts.class_instances());
            // canonical order: sorted, no duplicates
            for w in list.instances.windows(2) {
                assert!(w[0].verts < w[1].verts, "unsorted or duplicate instance");
            }
            // the per-instance histogram agrees with the materialized list
            let mut hist = vec![0u64; list.class_ids.len()];
            for i in &list.instances {
                hist[i.class_slot as usize] += 1;
            }
            assert_eq!(hist, list.per_class_seen);
        }
    }

    #[test]
    fn instance_limit_truncates_but_histogram_stays_exact() {
        let g = generators::gnp_undirected(40, 0.15, 4);
        let session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        let counts = session
            .count(&MotifQuery { direction: Direction::Undirected, ..Default::default() })
            .unwrap();
        assert!(counts.total_instances > 5);
        let q = MotifQuery {
            direction: Direction::Undirected,
            output: Output::Instances { limit: 5 },
            ..Default::default()
        };
        let list = match session.query(&q).unwrap() {
            QueryOutput::Instances(l) => l,
            other => panic!("{other:?}"),
        };
        assert_eq!(list.instances.len(), 5, "hard limit respected");
        assert!(list.truncated);
        assert_eq!(list.total_seen, counts.total_instances);
        assert_eq!(list.per_class_seen, counts.class_instances());
    }

    #[test]
    fn sample_is_identical_across_schedulers_and_reports_exact_seen() {
        let g = generators::barabasi_albert(120, 3, 8);
        let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
        let counts = session
            .count(&MotifQuery { direction: Direction::Undirected, ..Default::default() })
            .unwrap();
        let run = |scheduler| {
            let q = MotifQuery {
                direction: Direction::Undirected,
                scheduler,
                output: Output::Sample { per_class: 7, seed: 11 },
                ..Default::default()
            };
            match session.query(&q).unwrap() {
                QueryOutput::Sample(s) => s,
                other => panic!("{other:?}"),
            }
        };
        let base = run(SchedulerMode::SharedCursor);
        for scheduler in [SchedulerMode::WorkStealing, SchedulerMode::WorkStealingBatch] {
            let got = run(scheduler);
            for (a, b) in base.classes.iter().zip(&got.classes) {
                assert_eq!(a.seen, b.seen, "{scheduler:?}");
                assert_eq!(a.instances, b.instances, "{scheduler:?} sample must not move");
            }
        }
        // seen counts are the exact per-class totals
        let want = counts.class_instances();
        let got: Vec<u64> = base.classes.iter().map(|c| c.seen).collect();
        assert_eq!(got, want);
        for c in &base.classes {
            assert_eq!(c.instances.len() as u64, c.seen.min(7));
        }
    }

    #[test]
    fn top_vertices_ranking_matches_counts() {
        let g = generators::barabasi_albert(100, 3, 2);
        let session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        let counts = session
            .count(&MotifQuery { direction: Direction::Undirected, ..Default::default() })
            .unwrap();
        let q = MotifQuery {
            direction: Direction::Undirected,
            output: Output::TopVertices { k: 3 },
            ..Default::default()
        };
        let top = match session.query(&q).unwrap() {
            QueryOutput::TopVertices(t) => t,
            other => panic!("{other:?}"),
        };
        assert_eq!(top.total_instances, counts.total_instances);
        for (slot, ranked) in top.per_class.iter().enumerate() {
            assert!(ranked.len() <= 3);
            // ranked counts match the count matrix and are descending
            let mut prev = u64::MAX;
            for &(v, c) in ranked {
                assert_eq!(c, counts.vertex(v)[slot], "v{v} slot {slot}");
                assert!(c <= prev);
                prev = c;
            }
            // the top entry really is the maximum of the column
            if let Some(&(_, best)) = ranked.first() {
                let max = (0..counts.n as u32).map(|v| counts.vertex(v)[slot]).max().unwrap();
                assert_eq!(best, max);
            }
        }
    }

    #[test]
    fn scoped_counts_match_full_rows_restricted() {
        let g = generators::gnp_directed(70, 0.08, 19);
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let full = session
                    .count(&MotifQuery { size, direction: dir, ..Default::default() })
                    .unwrap();
                let scope_vs = vec![0u32, 7, 33];
                let (scoped, report) = session
                    .count_with_report(&MotifQuery {
                        size,
                        direction: dir,
                        scope: Scope::Vertices(scope_vs.clone()),
                        ..Default::default()
                    })
                    .unwrap();
                for &v in &scope_vs {
                    assert_eq!(scoped.vertex(v), full.vertex(v), "v{v} {size:?} {dir:?}");
                }
                for v in 0..g.n() as u32 {
                    if !scope_vs.contains(&v) {
                        assert!(scoped.vertex(v).iter().all(|&c| c == 0), "v{v} must be zeroed");
                    }
                }
                // the work-unit filter did real filtering
                assert!(report.queue_units <= g.und.m() / 2);
                assert!(scoped.total_instances <= full.total_instances);
                assert_eq!(
                    report.per_class_totals.iter().sum::<u64>(),
                    scoped.total_instances
                );
            }
        }
    }

    #[test]
    fn neighborhood_scope_covers_the_ball() {
        let g = generators::barabasi_albert(80, 3, 5);
        let session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        let full = session
            .count(&MotifQuery { direction: Direction::Undirected, ..Default::default() })
            .unwrap();
        let ball = session.neighborhood(&[4], 2).unwrap();
        assert!(ball.contains(&4));
        let scoped = session
            .count(&MotifQuery {
                direction: Direction::Undirected,
                scope: Scope::Neighborhood { seeds: vec![4], radius: 2 },
                ..Default::default()
            })
            .unwrap();
        for &v in &ball {
            assert_eq!(scoped.vertex(v), full.vertex(v), "v{v}");
        }
        for v in 0..g.n() as u32 {
            if !ball.contains(&v) {
                assert!(scoped.vertex(v).iter().all(|&c| c == 0));
            }
        }
    }

    #[test]
    fn scope_rejects_out_of_range_vertices() {
        let g = generators::star(10);
        let session = Session::load(&g);
        let q = MotifQuery {
            direction: Direction::Undirected,
            scope: Scope::Vertices(vec![99]),
            ..Default::default()
        };
        let err = session.count(&q).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn maintain_query_rejects_non_count_outputs_with_typed_error() {
        let g = generators::gnp_directed(30, 0.1, 2);
        let mut session = Session::load(&g);
        for output in [
            Output::Instances { limit: 10 },
            Output::Sample { per_class: 5, seed: 1 },
            Output::TopVertices { k: 3 },
        ] {
            let err = session
                .maintain_query(&MotifQuery { output, ..Default::default() })
                .unwrap_err();
            let typed = err.downcast_ref::<CountOnlyError>();
            assert!(typed.is_some(), "{output:?} must raise the typed error");
            assert!(err.to_string().contains("Count-only"), "{err}");
        }
        // scoped maintenance is equally rejected
        let err = session
            .maintain_query(&MotifQuery {
                scope: Scope::Vertices(vec![1]),
                ..Default::default()
            })
            .unwrap_err();
        assert!(err.downcast_ref::<CountOnlyError>().is_some());
        // the counts output registers fine
        session.maintain_query(&MotifQuery::default()).unwrap();
        assert_eq!(session.maintained().len(), 1);
    }

    // -------------------------------------------------------- streaming

    #[test]
    fn apply_edges_matches_reload_small() {
        let g = generators::gnp_directed(40, 0.1, 13);
        let mut session =
            Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        session.maintain(MotifSize::Four, Direction::Undirected).unwrap();

        let deltas = vec![
            EdgeDelta::insert(0, 5),
            EdgeDelta::insert(5, 0),
            EdgeDelta::delete(0, 5),
            EdgeDelta::insert(7, 8),
            EdgeDelta::delete(1, 2),
            EdgeDelta::insert(3, 3),    // self loop: invalid
            EdgeDelta::insert(0, 1000), // out of range: invalid
        ];
        let report = session.apply_edges(&deltas).unwrap();
        assert!(report.skipped_invalid >= 2);

        let snapshot = session.snapshot_graph();
        let fresh = Session::load_with(&snapshot, &SessionConfig::default());
        for (size, dir) in
            [(MotifSize::Three, Direction::Directed), (MotifSize::Four, Direction::Undirected)]
        {
            let maintained = session.maintained_counts(size, dir).unwrap();
            let want = fresh.count(&MotifQuery { size, direction: dir, ..Default::default() }).unwrap();
            assert_eq!(maintained.per_vertex, want.per_vertex, "{size:?} {dir:?}");
            assert_eq!(maintained.total_instances, want.total_instances);
        }
    }

    #[test]
    fn dirty_count_equals_compacted_count() {
        let g = generators::gnp_directed(50, 0.08, 21);
        // never compact automatically
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let deltas: Vec<EdgeDelta> =
            (0..20).map(|i| EdgeDelta::insert(i, (i * 7 + 3) % 50)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0, "overlay should be dirty");

        let q = MotifQuery { size: MotifSize::Four, direction: Direction::Directed, ..Default::default() };
        let dirty = session.count(&q).unwrap();

        let snapshot = session.snapshot_graph();
        let fresh = Session::load(&snapshot);
        let want = fresh.count(&q).unwrap();
        assert_eq!(dirty.per_vertex, want.per_vertex);
        assert_eq!(dirty.total_instances, want.total_instances);
    }

    #[test]
    fn scoped_and_instance_queries_stay_exact_over_dirty_overlay() {
        let g = generators::gnp_directed(45, 0.1, 27);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let deltas: Vec<EdgeDelta> =
            (0..15).map(|i| EdgeDelta::insert(i, (i * 11 + 2) % 45)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0);

        let fresh = Session::load(&session.snapshot_graph());
        // scoped counts over the dirty overlay equal the reload's rows
        let scope = Scope::Vertices(vec![1, 8, 20]);
        let dirty = session
            .count(&MotifQuery { scope: scope.clone(), ..Default::default() })
            .unwrap();
        let want = fresh.count(&MotifQuery { scope, ..Default::default() }).unwrap();
        assert_eq!(dirty.per_vertex, want.per_vertex);
        // instance lists too
        let q = MotifQuery { output: Output::Instances { limit: usize::MAX >> 1 }, ..Default::default() };
        let a = match session.query(&q).unwrap() {
            QueryOutput::Instances(l) => l,
            other => panic!("{other:?}"),
        };
        let b = match fresh.query(&q).unwrap() {
            QueryOutput::Instances(l) => l,
            other => panic!("{other:?}"),
        };
        assert_eq!(a.instances, b.instances);
    }

    #[test]
    fn compaction_triggers_and_preserves_counts() {
        let g = generators::gnp_undirected(40, 0.1, 9);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: 0.0, ..Default::default() },
        );
        session.maintain(MotifSize::Three, Direction::Undirected).unwrap();
        let deltas: Vec<EdgeDelta> =
            (0..10u32).map(|i| EdgeDelta::insert(i, (i + 13) % 40)).collect();
        let report = session.apply_edges(&deltas).unwrap();
        if report.applied() > 0 {
            assert_eq!(report.compactions, 1, "ratio 0.0 must compact every dirty batch");
            assert_eq!(session.overlay_entries(), 0);
        }
        let snapshot = session.snapshot_graph();
        let fresh = Session::load(&snapshot);
        let q = MotifQuery {
            size: MotifSize::Three,
            direction: Direction::Undirected,
            ..Default::default()
        };
        assert_eq!(
            session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap().per_vertex,
            fresh.count(&q).unwrap().per_vertex
        );
    }

    #[test]
    fn maintain_is_idempotent_and_listed() {
        let g = generators::gnp_directed(30, 0.1, 2);
        let mut session = Session::load(&g);
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        assert_eq!(session.maintained().len(), 1);
        assert!(session.maintained_counts(MotifSize::Four, Direction::Directed).is_none());
        let c = session.maintained_counts(MotifSize::Three, Direction::Directed).unwrap();
        let want = session
            .count(&MotifQuery { size: MotifSize::Three, ..Default::default() })
            .unwrap();
        assert_eq!(c.per_vertex, want.per_vertex);
    }

    #[test]
    fn adjacency_tiers_agree_and_report_memory() {
        let g = generators::barabasi_albert_directed(200, 4, 0.3, 12);
        let csr = Session::load_with(
            &g,
            &SessionConfig { workers: 2, adjacency: AdjacencyMode::Csr, ..Default::default() },
        );
        let hybrid = Session::load_with(
            &g,
            &SessionConfig {
                workers: 2,
                adjacency: AdjacencyMode::Hybrid,
                hub_threshold: Some(4),
                ..Default::default()
            },
        );
        assert_eq!(csr.tier_memory_bytes(), 0);
        assert!(hybrid.tier_memory_bytes() > 0);
        assert!(hybrid.hub_rows() > 0);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let q = MotifQuery { size, direction: dir, ..Default::default() };
                let (a, ra) = csr.count_with_report(&q).unwrap();
                let (b, rb) = hybrid.count_with_report(&q).unwrap();
                assert_eq!(a.per_vertex, b.per_vertex, "{size:?} {dir:?}");
                assert_eq!(a.total_instances, b.total_instances);
                assert_eq!(ra.tier_memory_bytes, 0);
                assert_eq!(rb.tier_memory_bytes, hybrid.tier_memory_bytes());
            }
        }
    }

    #[test]
    fn compaction_rebuilds_hybrid_tier() {
        let g = generators::gnp_directed(40, 0.1, 33);
        let mut session = Session::load_with(
            &g,
            &SessionConfig {
                workers: 2,
                compact_ratio: 0.0, // compact every dirty batch
                hub_threshold: Some(2),
                ..Default::default()
            },
        );
        let before = session.tier_memory_bytes();
        assert!(before > 0);
        let deltas: Vec<EdgeDelta> =
            (0..12u32).map(|i| EdgeDelta::insert(i, (i + 17) % 40)).collect();
        let report = session.apply_edges(&deltas).unwrap();
        assert!(report.compactions >= 1);
        assert!(
            session.tier_memory_bytes() > 0,
            "compaction must re-tier the rebuilt CSR"
        );
        // counts over the re-tiered CSR still match a fresh reload
        let q = MotifQuery { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() };
        let fresh = Session::load(&session.snapshot_graph());
        assert_eq!(
            session.count(&q).unwrap().per_vertex,
            fresh.count(&q).unwrap().per_vertex
        );
    }

    #[test]
    fn maintained_vertex_matches_materialized_rows() {
        let g = generators::gnp_directed(35, 0.1, 29);
        let mut session = Session::load(&g);
        let (size, dir) = (MotifSize::Three, Direction::Directed);
        assert!(session.maintained_vertex(size, dir, 0).is_none(), "nothing maintained yet");
        session.maintain(size, dir).unwrap();
        session.apply_edges(&[EdgeDelta::insert(0, 9), EdgeDelta::delete(1, 2)]).unwrap();
        let full = session.maintained_counts(size, dir).unwrap();
        for v in 0..g.n() as u32 {
            assert_eq!(session.maintained_vertex(size, dir, v).unwrap(), full.vertex(v), "v{v}");
        }
        assert!(session.maintained_vertex(size, dir, g.n() as u32).is_none(), "out of range");
        assert_eq!(session.n(), g.n());
    }

    #[test]
    fn builder_parses_cli_spellings_and_rejects_bad_ones() {
        let q = MotifQuery::builder()
            .size_k(4)
            .direction_name("undirected")
            .scheduler_name("stealing-batch")
            .sink_name("partition")
            .build()
            .unwrap();
        assert_eq!(q.size, MotifSize::Four);
        assert_eq!(q.direction, Direction::Undirected);
        assert_eq!(q.scheduler, SchedulerMode::WorkStealingBatch);
        assert_eq!(q.sink, CounterMode::PartitionLocal);
        assert_eq!(q.output, Output::Counts);
        assert_eq!(q.scope, Scope::All);

        // defaults match MotifQuery::default()
        let d = MotifQuery::builder().build().unwrap();
        assert_eq!(d, MotifQuery::default());

        assert!(MotifQuery::builder().size_k(5).build().is_err());
        assert!(MotifQuery::builder().direction_name("sideways").build().is_err());
        assert!(MotifQuery::builder().scheduler_name("fifo").build().is_err());
        assert!(MotifQuery::builder().sink_name("tree").build().is_err());
        // first error wins and names the bad knob
        let err = MotifQuery::builder()
            .size_k(9)
            .scheduler_name("fifo")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("3 or 4"), "{err}");
    }

    #[test]
    fn memory_bytes_tracks_session_state() {
        let g = generators::gnp_directed(60, 0.1, 7);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let base = session.memory_bytes();
        assert!(base >= g.und.memory_bytes(), "must cover at least the und CSR");

        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        let with_counter = session.memory_bytes();
        assert!(with_counter > base, "maintained counters must be accounted");

        let deltas: Vec<EdgeDelta> =
            (0..15u32).map(|i| EdgeDelta::insert(i, (i + 23) % 60)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0);
        assert!(
            session.memory_bytes() > with_counter,
            "a dirty overlay must grow the accounted bytes"
        );
    }

    #[test]
    fn graph_id_identity() {
        let g = generators::star(6);
        let mut session = Session::load(&g);
        assert_eq!(session.graph_id(), None);
        session.set_graph_id("stars/6");
        assert_eq!(session.graph_id(), Some("stars/6"));
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let g = generators::star(8);
        let mut session = Session::load(&g);
        session.maintain(MotifSize::Three, Direction::Undirected).unwrap();
        let before = session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap();
        let report = session.apply_edges(&[]).unwrap();
        assert_eq!(report.applied(), 0);
        assert_eq!(report.reenumerated_units, 0);
        let after = session.maintained_counts(MotifSize::Three, Direction::Undirected).unwrap();
        assert_eq!(before.per_vertex, after.per_vertex);
    }

    // -------------------------------------------------------- snapshots

    #[test]
    fn snapshots_pin_epochs_under_writes() {
        let g = generators::star(10);
        let mut session = Session::load(&g);
        assert_eq!(session.epoch(), 0);
        assert_eq!(session.pinned_snapshots(), 0);

        let q = MotifQuery { direction: Direction::Undirected, ..Default::default() };
        let pinned = session.snapshot();
        let before = pinned.count(&q).unwrap();

        session.apply_edges(&[EdgeDelta::insert(1, 2)]).unwrap();
        assert_eq!(session.epoch(), 1, "an applied batch commits one epoch");
        assert_eq!(pinned.epoch(), 0, "the pinned snapshot stays on its epoch");
        assert!(session.pinned_snapshots() >= 1, "the superseded epoch is pinned");

        // the pinned reader still sees the pre-batch graph, bit-identical
        let again = pinned.count(&q).unwrap();
        assert_eq!(again.per_vertex, before.per_vertex);
        assert_eq!(again.total_instances, before.total_instances);
        // while the head moved on (the 0-1-2 path became a triangle)
        let head = session.count(&q).unwrap();
        assert_ne!(head.per_vertex, before.per_vertex, "head must see the new edge");

        drop(pinned);
        assert_eq!(session.pinned_snapshots(), 0, "dropping the pin frees the epoch");
    }

    #[test]
    fn retained_bytes_meter_pinned_history() {
        let g = generators::gnp_directed(50, 0.08, 3);
        let mut session = Session::load_with(
            &g,
            &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
        );
        let deltas: Vec<EdgeDelta> =
            (0..12u32).map(|i| EdgeDelta::insert(i, (i * 5 + 1) % 50)).collect();
        session.apply_edges(&deltas).unwrap();
        assert!(session.overlay_entries() > 0);

        // pin the dirty epoch, then push another batch past it
        let pinned = session.snapshot();
        let head_only = pinned.memory_bytes();
        let more: Vec<EdgeDelta> =
            (12..24u32).map(|i| EdgeDelta::insert(i, (i * 7 + 2) % 50)).collect();
        session.apply_edges(&more).unwrap();

        assert!(session.retained_bytes() > 0, "pinned superseded overlay must be metered");
        assert!(
            session.memory_bytes() > head_only,
            "pool-visible bytes include pinned history"
        );
        let with_pin = session.memory_bytes();
        drop(pinned);
        assert_eq!(session.retained_bytes(), 0);
        assert!(session.memory_bytes() < with_pin, "freed history leaves the meter");
    }

    #[test]
    fn skipped_only_batches_do_not_commit() {
        let g = generators::star(8);
        let mut session = Session::load(&g);
        let report = session
            .apply_edges(&[EdgeDelta::insert(3, 3), EdgeDelta::delete(2, 5)])
            .unwrap();
        assert_eq!(report.applied(), 0);
        assert_eq!(session.epoch(), 0, "nothing changed, no epoch");
    }

    #[test]
    fn maintain_commits_one_epoch() {
        let g = generators::gnp_directed(30, 0.1, 8);
        let mut session = Session::load(&g);
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        assert_eq!(session.epoch(), 1);
        // idempotent re-registration does not commit
        session.maintain(MotifSize::Three, Direction::Directed).unwrap();
        assert_eq!(session.epoch(), 1);
        // a pinned pre-maintain snapshot has no counter; the head does
        let head = session.snapshot();
        assert_eq!(head.maintained().len(), 1);
        assert!(head.maintained_counts(MotifSize::Three, Direction::Directed).is_some());
    }

    #[test]
    fn concurrent_readers_on_shared_snapshots() {
        let g = generators::barabasi_albert(80, 3, 6);
        let session =
            Session::load_with(&g, &SessionConfig { workers: 1, ..Default::default() });
        let snap = session.snapshot();
        let q = MotifQuery { direction: Direction::Undirected, ..Default::default() };
        let want = snap.count(&q).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let snap = snap.clone();
                let q = q.clone();
                let want = &want;
                s.spawn(move || {
                    let got = snap.count(&q).unwrap();
                    assert_eq!(got.per_vertex, want.per_vertex);
                });
            }
        });
    }
}
