//! Cooperative cancellation for long-running enumerations.
//!
//! A [`CancelToken`] is a cheap, cloneable handle over a shared atomic
//! flag plus an optional deadline [`Instant`]. The service boundary
//! creates one per request (optionally as a [`CancelToken::child`] of a
//! per-connection token, so a vanished client or a server shutdown
//! cancels whatever that connection has in flight), and the engine's
//! worker loop polls it **once per work unit** — a unit is one
//! *(root, first-neighbor)* pair, the paper's grid cell, so a cancelled
//! or deadline-blown query stops within a single unit's cost instead of
//! running to completion and discarding the result.
//!
//! An aborted run surfaces as the typed [`QueryAborted`] error
//! (reachable through `anyhow::Error::downcast_ref`, like the stream
//! layer's `CountOnlyError`), carrying the [`AbortReason`] and exact
//! partial-progress accounting: work units completed vs scheduled. The
//! engine guarantees abort purity — a cancelled query never commits
//! state, so pool contents, snapshot epochs and maintained counters are
//! bit-identical to the query never having run (asserted by the
//! cancellation property tests).
//!
//! The happy-path cost is one relaxed atomic load per unit (plus one
//! clock read when a deadline is armed), benchmarked by the service
//! bench's `happy_path_overhead` row (≤ 2% asserted).

use std::fmt;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicU8, Ordering};
use crate::sync::Arc;

// Counter names shared by the engine (increment side, through the
// request's traced registry) and the service telemetry (pre-registration
// side, so scrapes show 0 before the first abort).
/// Queries aborted because their deadline passed.
pub const DEADLINE_EXCEEDED_TOTAL: &str = "vdmc_deadline_exceeded_total";
pub const HELP_DEADLINE_EXCEEDED: &str = "Queries aborted by an expired deadline.";
/// Queries aborted by an explicit cancel (client gone, shutdown, shed).
pub const CANCELLED_TOTAL: &str = "vdmc_cancelled_total";
pub const HELP_CANCELLED: &str = "Queries aborted by explicit cancellation (reason label).";
/// Worker or request panics contained by a catch_unwind boundary.
pub const PANICS_CAUGHT_TOTAL: &str = "vdmc_panics_caught_total";
pub const HELP_PANICS_CAUGHT: &str = "Panics caught at isolation boundaries instead of dying.";

/// Why an in-flight query was aborted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbortReason {
    /// The request's deadline (explicit `deadline_ms` or the serve
    /// default) passed while enumeration was still running.
    Deadline,
    /// The client vanished: its connection errored or a response write
    /// timed out, so nobody is waiting for the result.
    ClientGone,
    /// The server is draining for shutdown.
    Shutdown,
    /// Admission control revoked the request under overload.
    Shed,
}

impl AbortReason {
    /// Stable wire/metric label.
    pub fn label(&self) -> &'static str {
        match self {
            AbortReason::Deadline => "deadline",
            AbortReason::ClientGone => "client_gone",
            AbortReason::Shutdown => "shutdown",
            AbortReason::Shed => "shed",
        }
    }

    fn from_state(state: u8) -> Option<AbortReason> {
        match state {
            1 => Some(AbortReason::Deadline),
            2 => Some(AbortReason::ClientGone),
            3 => Some(AbortReason::Shutdown),
            4 => Some(AbortReason::Shed),
            _ => None,
        }
    }

    fn state(self) -> u8 {
        match self {
            AbortReason::Deadline => 1,
            AbortReason::ClientGone => 2,
            AbortReason::Shutdown => 3,
            AbortReason::Shed => 4,
        }
    }
}

impl fmt::Display for AbortReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

struct Inner {
    /// 0 = live; otherwise `AbortReason::state()`. First cancel wins.
    state: AtomicU8,
    /// Absolute deadline; checked (and latched into `state`) by `check`.
    deadline: Option<Instant>,
    /// Optional request label (the service tags tokens with the graph
    /// id); fault sites use it to scope injected faults to one graph.
    tag: Option<String>,
    /// Connection-level token this request token was derived from:
    /// cancelling the parent cancels every child.
    parent: Option<Arc<Inner>>,
}

impl Inner {
    fn reason(&self) -> Option<AbortReason> {
        // relaxed: the flag is a standalone latch — observers act on the
        // reason value itself and read no other memory published by the
        // cancelling thread, so no acquire edge is needed.
        AbortReason::from_state(self.state.load(Ordering::Relaxed))
    }
}

/// Shared cancellation flag + optional deadline. Clones observe the
/// same state; `check` is one relaxed load on the happy path.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CancelToken")
            .field("cancelled", &self.inner.reason())
            .field("deadline", &self.inner.deadline)
            .field("tag", &self.inner.tag)
            .finish()
    }
}

impl CancelToken {
    fn build(deadline: Option<Instant>, tag: Option<String>, parent: Option<Arc<Inner>>) -> Self {
        CancelToken {
            inner: Arc::new(Inner { state: AtomicU8::new(0), deadline, tag, parent }),
        }
    }

    /// A live token with no deadline.
    pub fn new() -> Self {
        CancelToken::build(None, None, None)
    }

    /// A token that reports [`AbortReason::Deadline`] once `deadline`
    /// passes.
    pub fn with_deadline(deadline: Instant) -> Self {
        CancelToken::build(Some(deadline), None, None)
    }

    /// A token whose deadline is `timeout` from now.
    pub fn after(timeout: Duration) -> Self {
        CancelToken::with_deadline(Instant::now() + timeout)
    }

    /// A per-request token derived from this (connection-level) token:
    /// it carries its own deadline and tag but also aborts when the
    /// parent is cancelled.
    pub fn child(&self, deadline: Option<Instant>, tag: Option<String>) -> CancelToken {
        CancelToken::build(deadline, tag, Some(Arc::clone(&self.inner)))
    }

    /// Request the abort. The first reason wins; returns whether this
    /// call was the one that cancelled the token.
    pub fn cancel(&self, reason: AbortReason) -> bool {
        // relaxed: first-reason-wins needs only the CAS's per-location
        // total order (exactly one transition from 0 sticks); the reason
        // travels inside the atomic itself, so there is nothing else to
        // publish.
        self.inner
            .state
            .compare_exchange(0, reason.state(), Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    /// Poll the token: the explicit flag (own, then parent chain), then
    /// the deadline. A passed deadline is latched into the flag so every
    /// later observer agrees on the reason.
    #[inline]
    pub fn check(&self) -> Option<AbortReason> {
        if let Some(r) = self.inner.reason() {
            return Some(r);
        }
        let mut up = self.inner.parent.as_deref();
        while let Some(p) = up {
            if let Some(r) = p.reason() {
                return Some(r);
            }
            up = p.parent.as_deref();
        }
        if let Some(d) = self.inner.deadline {
            if Instant::now() >= d {
                self.cancel(AbortReason::Deadline);
                return Some(self.inner.reason().unwrap_or(AbortReason::Deadline));
            }
        }
        None
    }

    /// Whether the token has been cancelled (deadline included).
    pub fn is_cancelled(&self) -> bool {
        self.check().is_some()
    }

    /// The armed deadline, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline
    }

    /// The request label (graph id) this token was tagged with.
    pub fn tag(&self) -> Option<&str> {
        self.inner.tag.as_deref()
    }
}

/// Typed abort error: the query stopped cooperatively without
/// committing anything. `units_done`/`units_total` are exact work-unit
/// progress at the moment the workers quiesced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueryAborted {
    /// Why the query stopped.
    pub reason: AbortReason,
    /// Work units fully enumerated before the stop.
    pub units_done: u64,
    /// Work units the scheduler had queued for the run.
    pub units_total: u64,
}

impl fmt::Display for QueryAborted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "query aborted ({}) after {}/{} work units",
            self.reason.label(),
            self.units_done,
            self.units_total
        )
    }
}

impl std::error::Error for QueryAborted {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_cancel_wins_and_clones_share_state() {
        let t = CancelToken::new();
        assert_eq!(t.check(), None);
        let c = t.clone();
        assert!(c.cancel(AbortReason::Shutdown));
        assert!(!t.cancel(AbortReason::ClientGone), "second cancel loses");
        assert_eq!(t.check(), Some(AbortReason::Shutdown));
        assert_eq!(c.check(), Some(AbortReason::Shutdown));
    }

    #[test]
    fn deadline_latches_into_the_flag() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.check(), Some(AbortReason::Deadline));
        // latched: an explicit cancel afterwards cannot change the reason
        t.cancel(AbortReason::Shutdown);
        assert_eq!(t.check(), Some(AbortReason::Deadline));
    }

    #[test]
    fn future_deadline_stays_live() {
        let t = CancelToken::after(Duration::from_secs(3600));
        assert_eq!(t.check(), None);
        assert!(t.deadline().is_some());
    }

    #[test]
    fn child_sees_parent_cancellation_but_keeps_its_own_deadline() {
        let conn = CancelToken::new();
        let req = conn.child(None, Some("g1".into()));
        assert_eq!(req.tag(), Some("g1"));
        assert_eq!(req.check(), None);
        conn.cancel(AbortReason::ClientGone);
        assert_eq!(req.check(), Some(AbortReason::ClientGone));
        assert_eq!(conn.check(), Some(AbortReason::ClientGone));

        let conn2 = CancelToken::new();
        let req2 = conn2.child(Some(Instant::now() - Duration::from_millis(1)), None);
        assert_eq!(req2.check(), Some(AbortReason::Deadline));
        assert_eq!(conn2.check(), None, "a child's deadline never cancels the parent");
    }

    #[test]
    fn cancel_propagates_down_the_whole_child_chain() {
        let conn = CancelToken::new();
        let req = conn.child(None, None);
        let unit = req.child(None, Some("unit".into()));
        assert_eq!(unit.check(), None);
        conn.cancel(AbortReason::Shutdown);
        assert_eq!(unit.check(), Some(AbortReason::Shutdown), "grandchild sees the root cancel");
        assert_eq!(req.check(), Some(AbortReason::Shutdown));
        // a child derived after the cancel is born cancelled
        assert_eq!(req.child(None, None).check(), Some(AbortReason::Shutdown));
        // first-reason-wins is per token, and check() reads own latch
        // before walking up: a later cancel on the middle token relabels
        // its own subtree but can never reach the root
        assert!(req.cancel(AbortReason::Shed), "req's own latch was still unset");
        assert_eq!(req.check(), Some(AbortReason::Shed));
        assert_eq!(unit.check(), Some(AbortReason::Shed), "nearest cancelled ancestor wins");
        assert_eq!(conn.check(), Some(AbortReason::Shutdown), "the root keeps its reason");
    }

    #[test]
    fn query_aborted_displays_progress_and_downcasts() {
        let err: anyhow::Error =
            QueryAborted { reason: AbortReason::Deadline, units_done: 3, units_total: 10 }.into();
        let aborted = err.downcast_ref::<QueryAborted>().expect("typed abort");
        assert_eq!(aborted.reason, AbortReason::Deadline);
        assert!(err.to_string().contains("3/10 work units"), "{err}");
    }
}
