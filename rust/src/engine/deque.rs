//! Claim primitives under the scheduler layer: the shared fetch-add
//! cursor and the per-worker steal deques.
//!
//! [`super::scheduler`] wraps these in the query-facing `Scheduler`
//! trait and adds trace-phase timing; this module holds only the
//! synchronization, generic over the item type and importing every
//! primitive from [`crate::sync`], so `cfg(loom)` builds model-check
//! the claim/steal protocol itself (`tests/loom_models.rs` asserts
//! every item is claimed exactly once across all interleavings).
//!
//! Termination stays sound under batching: items only ever move from a
//! victim's deque into the thief's hands and deque, so the total item
//! count across queues is non-increasing and every item is claimed by
//! exactly one worker. A worker that sweeps every queue empty may exit
//! while a thief still drains its own transferred batch — that costs
//! tail parallelism, never correctness, because counter updates
//! commute.

use std::collections::VecDeque;

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;
use crate::util::rng::Pcg32;

/// One claimed item plus where it came from.
#[derive(Debug, Clone, Copy)]
pub struct Claimed<T> {
    pub item: T,
    /// True when the item came from another worker's deque.
    pub stolen: bool,
    /// Items transferred by the steal operation that produced this
    /// claim (1 for single-item steals, half the victim's deque for
    /// batch steals, 0 for local pops).
    pub batch: u32,
}

/// Shared pull-cursor over a flat queue: workers claim the next item
/// with a single relaxed fetch-add — lock-free dynamic load balancing.
pub struct CursorQueue<T> {
    items: Vec<T>,
    cursor: AtomicUsize,
}

impl<T: Copy> CursorQueue<T> {
    pub fn new(items: Vec<T>) -> CursorQueue<T> {
        CursorQueue { items, cursor: AtomicUsize::new(0) }
    }

    /// Claim the next item; `None` once the queue is drained (a
    /// terminal state — later calls also return `None`).
    #[inline]
    pub fn claim(&self) -> Option<T> {
        // relaxed: the RMW total order on `cursor` alone guarantees each
        // index is handed out once; the items themselves are immutable
        // after construction and published to the workers by the
        // spawn/join happens-before, not by this counter.
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.items.get(i).copied()
    }

    /// Total items managed by this queue (claimed or not).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Per-worker deques with randomized FIFO stealing (single-item or
/// half-deque batches).
///
/// Each deque is stored reversed so `pop_back` (the LIFO local pop)
/// serves items in seed order — heaviest work first, cache-warm —
/// while thieves `pop_front` the cheap tail.
pub struct StealDeques<T> {
    queues: Vec<Mutex<VecDeque<T>>>,
    /// Per-worker PRNG picking the steal-sweep start (deterministic
    /// seeds keep runs reproducible; results don't depend on steal
    /// order anyway).
    rngs: Vec<Mutex<Pcg32>>,
    n_items: usize,
    /// Steal half of the victim's deque instead of one item.
    steal_half: bool,
}

impl<T: Copy> StealDeques<T> {
    /// `per_worker[w]` seeds worker w's deque; items must be in
    /// scheduling order (most expensive first).
    pub fn new(per_worker: Vec<Vec<T>>, steal_half: bool) -> StealDeques<T> {
        let n_items = per_worker.iter().map(|q| q.len()).sum();
        let n_workers = per_worker.len();
        let queues = per_worker
            .into_iter()
            .map(|mut items| {
                items.reverse();
                Mutex::new(VecDeque::from(items))
            })
            .collect();
        let rngs = (0..n_workers)
            .map(|w| Mutex::new(Pcg32::new(0x5EED ^ w as u64, w as u64)))
            .collect();
        StealDeques { queues, rngs, n_items, steal_half }
    }

    /// Claim the next item for `worker_id`: a local LIFO pop, else a
    /// randomized circular steal sweep. `None` once every deque is
    /// drained (terminal — later calls also return `None`).
    pub fn claim(&self, worker_id: usize) -> Option<Claimed<T>> {
        let nq = self.queues.len();
        if nq == 0 {
            return None;
        }
        let home = worker_id % nq;
        if let Some(item) = self.queues[home].lock().unwrap().pop_back() {
            return Some(Claimed { item, stolen: false, batch: 0 });
        }
        // Home deque dry: circular sweep over the victims from a random
        // start (randomizes contention without allocating per claim).
        let start = self.rngs[home].lock().unwrap().below_usize(nq);
        for offset in 0..nq {
            let q = (start + offset) % nq;
            if q == home {
                continue;
            }
            let mut victim = self.queues[q].lock().unwrap();
            if victim.is_empty() {
                continue;
            }
            if !self.steal_half {
                let item = victim.pop_front().unwrap();
                return Some(Claimed { item, stolen: true, batch: 1 });
            }
            // Batch steal: drain the front half (the victim's cheap
            // tail) in one go, then release the victim before touching
            // the home deque — no two locks held at once.
            let take = victim.len().div_ceil(2);
            let mut taken: Vec<T> = victim.drain(..take).collect();
            drop(victim);
            let first = taken.remove(0);
            if !taken.is_empty() {
                // Front-of-victim order is cheapest-last; pushing it
                // back-to-back keeps the home pop_back yielding the
                // heaviest item of the batch first.
                self.queues[home].lock().unwrap().extend(taken);
            }
            return Some(Claimed { item: first, stolen: true, batch: take as u32 });
        }
        None
    }

    /// Total items seeded across all deques (claimed or not).
    pub fn len(&self) -> usize {
        self.n_items
    }

    pub fn is_empty(&self) -> bool {
        self.n_items == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miri_cursor_hands_out_each_index_once() {
        let q = CursorQueue::new((0..10u32).collect());
        let mut seen: Vec<u32> = Vec::new();
        while let Some(v) = q.claim() {
            seen.push(v);
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert!(q.claim().is_none());
        assert_eq!(q.len(), 10);
        assert!(!q.is_empty());
    }

    #[test]
    fn miri_steal_deques_drain_exactly_once() {
        for steal_half in [false, true] {
            let seeds = vec![(0..50u32).collect(), Vec::new(), (50..64).collect()];
            let d = StealDeques::new(seeds, steal_half);
            assert_eq!(d.len(), 64);
            let mut claimed: Vec<u32> = Vec::new();
            for w in 0..3 {
                while let Some(c) = d.claim(w) {
                    claimed.push(c.item);
                }
            }
            claimed.sort_unstable();
            assert_eq!(claimed, (0..64).collect::<Vec<_>>(), "steal_half={steal_half}");
        }
    }

    #[test]
    fn empty_deques_terminate() {
        let d: StealDeques<u32> = StealDeques::new(vec![], false);
        assert!(d.claim(0).is_none());
        assert!(d.is_empty());
    }
}
