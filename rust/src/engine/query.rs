//! Query surface: what one enumeration request against a loaded
//! [`crate::engine::Session`] asks for.
//!
//! [`MotifQuery`] widens the historical `CountQuery` along two new axes:
//!
//! - [`Output`] — what the emission pipeline produces. `Counts` is the
//!   paper's per-vertex count matrix (bit-identical to the pre-redesign
//!   sinks); `Instances` materializes the enumerated instances themselves
//!   (bounded by a hard `limit`); `Sample` keeps a per-class uniform
//!   reservoir of instances, reproducible for a fixed seed under any
//!   scheduler; `TopVertices` ranks the busiest vertices per class.
//! - [`Scope`] — which part of the graph the query covers. Scoping
//!   filters at the **work-unit level**: only (root, neighbor) units
//!   whose root can own an in-scope instance are enumerated (the root of
//!   a k-set is its minimal member, and a connected k-set has diameter
//!   ≤ k-1, so the candidate roots are the (k-1)-hop ball around the
//!   scope set). Scoped queries therefore do neighborhood-local work,
//!   not a full pass plus post-filter.
//!
//! [`MotifQuery::builder`] stays the one validating construction path
//! shared by the CLI flags, the service wire codec and the benches, so
//! the accepted knob spellings cannot drift between surfaces.

use anyhow::{bail, Result};

use crate::motifs::counter::{CounterMode, MotifCounts};
use crate::motifs::{Direction, MotifSize};
use crate::util::json::Json;

use super::scheduler::SchedulerMode;

/// What the emission pipeline should produce for a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Output {
    /// Per-vertex class counts (the paper's deliverable; the default).
    Counts,
    /// The enumerated instances themselves, up to a hard `limit`;
    /// [`InstanceList::truncated`] reports whether the limit cut the
    /// stream short.
    Instances { limit: usize },
    /// A uniform per-class reservoir of up to `per_class` instances.
    /// Selection is keyed on (seed, instance), so a fixed seed yields the
    /// identical sample under every scheduler and worker count.
    Sample { per_class: usize, seed: u64 },
    /// The `k` busiest vertices per class, ranked by count.
    TopVertices { k: usize },
}

impl Output {
    /// The CLI/wire spelling of this output kind.
    pub fn label(&self) -> &'static str {
        match self {
            Output::Counts => "counts",
            Output::Instances { .. } => "instances",
            Output::Sample { .. } => "sample",
            Output::TopVertices { .. } => "top-vertices",
        }
    }

    /// Parse an output kind from its CLI/wire spelling with default
    /// parameters (used where only the kind matters, e.g. rejecting
    /// non-count outputs on the maintenance path).
    pub fn parse_default(name: &str) -> Option<Output> {
        match name {
            "counts" => Some(Output::Counts),
            "instances" => Some(Output::Instances { limit: 1000 }),
            "sample" => Some(Output::Sample { per_class: 10, seed: 42 }),
            "top-vertices" | "top" => Some(Output::TopVertices { k: 10 }),
            _ => None,
        }
    }
}

/// Which part of the graph a query covers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Scope {
    /// The whole graph (the default).
    All,
    /// Exactly these vertices (original ids): results cover every
    /// instance containing at least one of them.
    Vertices(Vec<u32>),
    /// The closed `radius`-hop undirected neighborhood of `seeds`
    /// (original ids): results cover every instance touching that ball.
    Neighborhood { seeds: Vec<u32>, radius: usize },
}

impl Scope {
    pub fn is_all(&self) -> bool {
        matches!(self, Scope::All)
    }

    /// The CLI/wire spelling of this scope kind.
    pub fn label(&self) -> &'static str {
        match self {
            Scope::All => "all",
            Scope::Vertices(_) => "vertices",
            Scope::Neighborhood { .. } => "neighborhood",
        }
    }
}

/// One enumeration request against a loaded session. `CountQuery` remains
/// as the compatibility alias; struct-literal construction with
/// `..Default::default()` keeps working unchanged.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MotifQuery {
    pub size: MotifSize,
    pub direction: Direction,
    pub scheduler: SchedulerMode,
    /// Counter-update strategy of the Count output (ignored by the other
    /// outputs, which carry their own accumulation state).
    pub sink: CounterMode,
    pub output: Output,
    pub scope: Scope,
}

/// Historical name of [`MotifQuery`] (the counts-only era). Every old
/// call site keeps compiling; new code should say `MotifQuery`.
pub type CountQuery = MotifQuery;

impl Default for MotifQuery {
    fn default() -> Self {
        MotifQuery {
            size: MotifSize::Three,
            direction: Direction::Directed,
            scheduler: SchedulerMode::WorkStealing,
            sink: CounterMode::Sharded,
            output: Output::Counts,
            scope: Scope::All,
        }
    }
}

impl MotifQuery {
    /// Validating builder — the one construction path shared by the CLI,
    /// the service wire codec and the benches, so the accepted knob names
    /// (`stealing-batch`, `partition`, `sample`, ...) can't drift between
    /// surfaces.
    pub fn builder() -> MotifQueryBuilder {
        MotifQueryBuilder::default()
    }
}

/// Historical name of [`MotifQueryBuilder`].
pub type CountQueryBuilder = MotifQueryBuilder;

/// Builder behind [`MotifQuery::builder`]. Typed setters are infallible;
/// the `*_name` setters parse the CLI/wire spellings and defer their
/// error to [`MotifQueryBuilder::build`], so call sites chain without
/// intermediate `?`s.
#[derive(Debug, Clone, Default)]
pub struct MotifQueryBuilder {
    query: MotifQuery,
    err: Option<String>,
}

impl MotifQueryBuilder {
    pub fn size(mut self, size: MotifSize) -> Self {
        self.query.size = size;
        self
    }

    /// Motif size from its integer spelling (3 or 4).
    pub fn size_k(mut self, k: usize) -> Self {
        match MotifSize::from_k(k) {
            Some(s) => self.query.size = s,
            None => self.fail(format!("motif size must be 3 or 4, got {k}")),
        }
        self
    }

    pub fn direction(mut self, direction: Direction) -> Self {
        self.query.direction = direction;
        self
    }

    /// Direction from its wire spelling: `directed` | `undirected`.
    pub fn direction_name(mut self, name: &str) -> Self {
        match Direction::parse(name) {
            Some(d) => self.query.direction = d,
            None => self.fail(format!("unknown direction {name:?} (directed | undirected)")),
        }
        self
    }

    pub fn scheduler(mut self, scheduler: SchedulerMode) -> Self {
        self.query.scheduler = scheduler;
        self
    }

    /// Scheduler from its CLI spelling: `cursor` | `stealing` |
    /// `stealing-batch`.
    pub fn scheduler_name(mut self, name: &str) -> Self {
        match name {
            "cursor" => self.query.scheduler = SchedulerMode::SharedCursor,
            "stealing" => self.query.scheduler = SchedulerMode::WorkStealing,
            "stealing-batch" => self.query.scheduler = SchedulerMode::WorkStealingBatch,
            _ => self.fail(format!(
                "unknown scheduler {name:?} (cursor | stealing | stealing-batch)"
            )),
        }
        self
    }

    pub fn sink(mut self, sink: CounterMode) -> Self {
        self.query.sink = sink;
        self
    }

    /// Counter sink from its CLI spelling: `atomic` | `sharded` |
    /// `partition`.
    pub fn sink_name(mut self, name: &str) -> Self {
        match name {
            "atomic" => self.query.sink = CounterMode::Atomic,
            "sharded" => self.query.sink = CounterMode::Sharded,
            "partition" => self.query.sink = CounterMode::PartitionLocal,
            _ => self.fail(format!("unknown sink {name:?} (atomic | sharded | partition)")),
        }
        self
    }

    pub fn output(mut self, output: Output) -> Self {
        self.query.output = output;
        self
    }

    /// Instances output with a hard cap on materialized instances.
    pub fn instances(self, limit: usize) -> Self {
        self.output(Output::Instances { limit })
    }

    /// Per-class reservoir-sample output.
    pub fn sample(self, per_class: usize, seed: u64) -> Self {
        self.output(Output::Sample { per_class, seed })
    }

    /// Per-class top-k-vertices output.
    pub fn top_vertices(self, k: usize) -> Self {
        self.output(Output::TopVertices { k })
    }

    pub fn scope(mut self, scope: Scope) -> Self {
        self.query.scope = scope;
        self
    }

    /// Restrict the query to instances touching these vertices.
    pub fn scope_vertices(self, vertices: Vec<u32>) -> Self {
        self.scope(Scope::Vertices(vertices))
    }

    /// Restrict the query to the `radius`-hop neighborhood of `seeds`.
    pub fn neighborhood(self, seeds: Vec<u32>, radius: usize) -> Self {
        self.scope(Scope::Neighborhood { seeds, radius })
    }

    fn fail(&mut self, msg: String) {
        // first error wins: it names the knob the caller got wrong
        if self.err.is_none() {
            self.err = Some(msg);
        }
    }

    pub fn build(mut self) -> Result<MotifQuery> {
        // parameter validation happens here (not in the setters) so the
        // first *spelling* error still wins over a parameter error
        if self.err.is_none() {
            match self.query.output {
                Output::Instances { limit } if limit == 0 => {
                    self.fail("instances output needs a limit >= 1".to_string())
                }
                Output::Sample { per_class, .. } if per_class == 0 => {
                    self.fail("sample output needs per_class >= 1".to_string())
                }
                Output::TopVertices { k } if k == 0 => {
                    self.fail("top-vertices output needs k >= 1".to_string())
                }
                _ => {}
            }
        }
        if self.err.is_none() {
            match &self.query.scope {
                Scope::Vertices(vs) if vs.is_empty() => {
                    self.fail("vertex scope needs at least one vertex".to_string())
                }
                Scope::Neighborhood { seeds, .. } if seeds.is_empty() => {
                    self.fail("neighborhood scope needs at least one seed".to_string())
                }
                _ => {}
            }
        }
        match self.err {
            Some(msg) => bail!("{msg}"),
            None => Ok(self.query),
        }
    }
}

// ------------------------------------------------------------- vertex bits

/// Compact vertex bitset (one bit per processing id) used for scope
/// membership tests on the emission path and root filtering at the
/// work-unit level.
#[derive(Debug, Clone, Default)]
pub struct VertexBits {
    words: Vec<u64>,
    count: usize,
}

impl VertexBits {
    pub fn new(n: usize) -> VertexBits {
        VertexBits { words: vec![0u64; n.div_ceil(64)], count: 0 }
    }

    /// Insert `v`; true when it was not present before.
    pub fn insert(&mut self, v: u32) -> bool {
        let (w, b) = (v as usize / 64, v as usize % 64);
        debug_assert!(w < self.words.len(), "vertex {v} beyond bitset width");
        let mask = 1u64 << b;
        if self.words[w] & mask == 0 {
            self.words[w] |= mask;
            self.count += 1;
            true
        } else {
            false
        }
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        let w = v as usize / 64;
        w < self.words.len() && self.words[w] & (1u64 << (v as usize % 64)) != 0
    }

    /// True when any of `vs` is a member (the per-instance scope test).
    #[inline]
    pub fn contains_any(&self, vs: &[u32]) -> bool {
        vs.iter().any(|&v| self.contains(v))
    }

    /// Members inserted so far.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Members in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut rest = word;
            std::iter::from_fn(move || {
                if rest == 0 {
                    return None;
                }
                let b = rest.trailing_zeros();
                rest &= rest - 1;
                Some(wi as u32 * 64 + b)
            })
        })
    }
}

// ------------------------------------------------------------ result types

/// One materialized motif instance in ORIGINAL vertex ids, members sorted
/// ascending. `class_slot` indexes the query's compact class space (see
/// the `class_ids` column labels on the carrying result).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MotifInstance {
    pub verts: Vec<u32>,
    pub class_slot: u16,
}

/// The [`Output::Instances`] result: the enumerated instances themselves,
/// canonically ordered (each instance's vertices ascending, instances
/// sorted lexicographically) so untruncated lists are deterministic under
/// any scheduler.
#[derive(Debug, Clone)]
pub struct InstanceList {
    pub k: usize,
    pub direction: Direction,
    /// Canonical class id per slot (column labels).
    pub class_ids: Vec<u16>,
    pub instances: Vec<MotifInstance>,
    /// True when more instances were enumerated than `limit` kept; which
    /// instances survive a truncated run depends on scheduling — only
    /// untruncated lists are deterministic.
    pub truncated: bool,
    /// Instances enumerated (and, under a scope, accepted) in total.
    pub total_seen: u64,
    /// Per-slot instance totals over the whole run (exact even when the
    /// materialized list is truncated).
    pub per_class_seen: Vec<u64>,
}

impl InstanceList {
    /// Canonical class id of an instance's slot.
    pub fn class_id(&self, slot: u16) -> u16 {
        self.class_ids[slot as usize]
    }

    pub fn to_json(&self) -> Json {
        let mut classes = Json::obj();
        for (cid, &seen) in self.class_ids.iter().zip(&self.per_class_seen) {
            classes.set(&format!("m{cid}"), seen);
        }
        let rows: Vec<Json> = self
            .instances
            .iter()
            .map(|i| {
                Json::Arr(vec![
                    Json::from(i.verts.clone()),
                    Json::from(self.class_id(i.class_slot) as u64),
                ])
            })
            .collect();
        let mut j = Json::obj();
        j.set("k", self.k)
            .set("direction", self.direction.label())
            .set("count", self.instances.len())
            .set("truncated", self.truncated)
            .set("total_seen", self.total_seen)
            .set("classes", classes)
            .set("instances", Json::Arr(rows));
        j
    }
}

/// One class's reservoir from an [`Output::Sample`] run.
#[derive(Debug, Clone)]
pub struct ClassSample {
    /// Compact slot this reservoir covers.
    pub slot: u16,
    /// Canonical class id (the `m<id>` label).
    pub class_id: u16,
    /// Instances of this class enumerated in total (exact).
    pub seen: u64,
    /// Up to `per_class` uniformly sampled instances, in selection-key
    /// order (deterministic for a fixed seed).
    pub instances: Vec<MotifInstance>,
}

/// The [`Output::Sample`] result: a per-class uniform reservoir plus the
/// exact per-class totals the sample was drawn from.
#[derive(Debug, Clone)]
pub struct SampleSummary {
    pub k: usize,
    pub direction: Direction,
    pub per_class: usize,
    pub seed: u64,
    /// One entry per class slot (empty classes keep `seen == 0`).
    pub classes: Vec<ClassSample>,
    /// Instances enumerated (and, under a scope, accepted) in total.
    pub total_seen: u64,
}

impl SampleSummary {
    pub fn to_json(&self) -> Json {
        let mut classes = Json::obj();
        for c in &self.classes {
            if c.seen == 0 {
                continue;
            }
            let rows: Vec<Json> =
                c.instances.iter().map(|i| Json::from(i.verts.clone())).collect();
            let mut o = Json::obj();
            o.set("seen", c.seen).set("sample", Json::Arr(rows));
            classes.set(&format!("m{}", c.class_id), o);
        }
        let mut j = Json::obj();
        j.set("k", self.k)
            .set("direction", self.direction.label())
            .set("per_class", self.per_class)
            .set("seed", self.seed)
            .set("total_seen", self.total_seen)
            .set("classes", classes);
        j
    }
}

/// The [`Output::TopVertices`] result: per class, the busiest vertices by
/// count (ORIGINAL ids, count descending, vertex id ascending on ties).
#[derive(Debug, Clone)]
pub struct TopVertices {
    pub k: usize,
    pub direction: Direction,
    pub class_ids: Vec<u16>,
    /// Requested ranking depth.
    pub top_k: usize,
    /// `per_class[slot]` = up to `top_k` (vertex, count) pairs.
    pub per_class: Vec<Vec<(u32, u64)>>,
    pub total_instances: u64,
}

impl TopVertices {
    pub fn to_json(&self) -> Json {
        let mut classes = Json::obj();
        for (cid, rows) in self.class_ids.iter().zip(&self.per_class) {
            if rows.is_empty() {
                continue;
            }
            let rows: Vec<Json> = rows
                .iter()
                .map(|&(v, c)| Json::Arr(vec![Json::from(v as u64), Json::from(c)]))
                .collect();
            classes.set(&format!("m{cid}"), Json::Arr(rows));
        }
        let mut j = Json::obj();
        j.set("k", self.k)
            .set("direction", self.direction.label())
            .set("top", self.top_k)
            .set("total_instances", self.total_instances)
            .set("classes", classes);
        j
    }
}

/// What a [`crate::engine::Session::query`] call produced — one variant
/// per [`Output`] kind.
#[derive(Debug, Clone)]
pub enum QueryOutput {
    Counts(MotifCounts),
    Instances(InstanceList),
    Sample(SampleSummary),
    TopVertices(TopVertices),
}

impl QueryOutput {
    /// The [`Output`] spelling this result came from.
    pub fn label(&self) -> &'static str {
        match self {
            QueryOutput::Counts(_) => "counts",
            QueryOutput::Instances(_) => "instances",
            QueryOutput::Sample(_) => "sample",
            QueryOutput::TopVertices(_) => "top-vertices",
        }
    }

    /// Unwrap a Counts result; `None` for the other variants.
    pub fn into_counts(self) -> Option<MotifCounts> {
        match self {
            QueryOutput::Counts(c) => Some(c),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vertex_bits_basics() {
        let mut b = VertexBits::new(130);
        assert!(b.is_empty());
        assert!(b.insert(0));
        assert!(b.insert(129));
        assert!(b.insert(64));
        assert!(!b.insert(64), "double insert reports existing");
        assert_eq!(b.len(), 3);
        assert!(b.contains(0) && b.contains(64) && b.contains(129));
        assert!(!b.contains(1) && !b.contains(128));
        assert!(!b.contains(10_000), "out-of-width probe is just false");
        assert!(b.contains_any(&[5, 64]));
        assert!(!b.contains_any(&[5, 63]));
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![0, 64, 129]);
    }

    #[test]
    fn builder_validates_output_and_scope_parameters() {
        assert!(MotifQuery::builder().instances(0).build().is_err());
        assert!(MotifQuery::builder().sample(0, 1).build().is_err());
        assert!(MotifQuery::builder().top_vertices(0).build().is_err());
        assert!(MotifQuery::builder().scope_vertices(vec![]).build().is_err());
        assert!(MotifQuery::builder().neighborhood(vec![], 2).build().is_err());

        let q = MotifQuery::builder()
            .size_k(4)
            .sample(16, 7)
            .neighborhood(vec![3, 9], 2)
            .build()
            .unwrap();
        assert_eq!(q.output, Output::Sample { per_class: 16, seed: 7 });
        assert_eq!(q.scope, Scope::Neighborhood { seeds: vec![3, 9], radius: 2 });

        // first (spelling) error still wins over parameter validation
        let err = MotifQuery::builder()
            .scheduler_name("fifo")
            .instances(0)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("fifo"), "{err}");
    }

    #[test]
    fn output_parse_labels_roundtrip() {
        for name in ["counts", "instances", "sample", "top-vertices"] {
            let o = Output::parse_default(name).unwrap();
            assert_eq!(o.label(), name);
        }
        assert!(Output::parse_default("histogram").is_none());
        assert_eq!(Scope::All.label(), "all");
        assert_eq!(Scope::Vertices(vec![1]).label(), "vertices");
        assert_eq!(Scope::Neighborhood { seeds: vec![1], radius: 1 }.label(), "neighborhood");
    }
}
