//! Partition layer: contiguous vertex-range shards balanced by degree mass.
//!
//! The unit of parallel work is a (root, first-neighbor) pair — the same
//! decomposition the paper uses for its CUDA grid (Section 6: "each pair
//! of a vertex and one of its neighbors is computed separately ... prevents
//! waiting for a small number of vertices with a very high degree"). Units
//! are batched into [`WorkItem`] ranges so queue traffic stays low.
//!
//! On top of the flat item list this module adds [`PartitionSet`]: the
//! relabeled (degree-descending) vertex space is split into contiguous
//! ranges whose *unit budgets* — not vertex counts — are even. On a
//! heavy-tailed graph the first shard may be a single hub vertex while the
//! last holds thousands of degree-1 tails; each worker's home shard then
//! seeds its local deque ([`super::scheduler`]) and defines the vertex
//! range its partition-local counter writes without synchronization
//! ([`super::sink`]).

use crate::graph::GraphProbe;

/// A contiguous range of first-neighbor units for one root.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkItem {
    pub root: u32,
    /// First-neighbor index range [j_start, j_end) into the root's proper
    /// neighbor list.
    pub j_start: u32,
    pub j_end: u32,
}

impl WorkItem {
    pub fn units(&self) -> usize {
        (self.j_end - self.j_start) as usize
    }
}

/// Number of (root, first-neighbor) units a root contributes = its
/// proper-neighbor count in the (relabeled) undirected view. Generic over
/// [`GraphProbe`] so the stream layer can budget work items for a delta
/// overlay without materializing it.
#[inline]
pub fn root_units<G: GraphProbe>(graph: &G, root: u32) -> usize {
    graph.und_degree_above(root, root)
}

/// Append the items of one root, chunked to `max_units_per_item`.
fn push_root_items(items: &mut Vec<WorkItem>, root: u32, units: usize, max_units_per_item: usize) {
    let units = units as u32;
    let max = max_units_per_item as u32;
    let mut j = 0u32;
    while j < units {
        let end = (j + max).min(units);
        items.push(WorkItem { root, j_start: j, j_end: end });
        j = end;
    }
}

/// Build the flat work-item list for a (relabeled) graph, roots ascending.
///
/// `max_units_per_item` bounds item granularity: hubs are split into many
/// items (the paper's high-degree division), while degree-1 tails stay one
/// item each.
pub fn build_items<G: GraphProbe>(graph: &G, max_units_per_item: usize) -> Vec<WorkItem> {
    assert!(max_units_per_item >= 1);
    let mut items = Vec::new();
    for root in 0..graph.n() as u32 {
        push_root_items(&mut items, root, root_units(graph, root), max_units_per_item);
    }
    items
}

/// Total units across an item list (= number of proper (root, neighbor)
/// pairs = |E| of the undirected view).
pub fn total_units(items: &[WorkItem]) -> usize {
    items.iter().map(|i| i.units()).sum()
}

/// One shard: a contiguous processing-id range plus its work items.
#[derive(Debug, Clone)]
pub struct Shard {
    pub index: usize,
    /// Home vertex range [v_start, v_end) in processing (relabeled) ids.
    pub v_start: u32,
    pub v_end: u32,
    /// Unit budget of this shard (sum of its roots' proper degrees).
    pub units: usize,
    /// Work items whose root lies in the home range, roots ascending.
    pub items: Vec<WorkItem>,
}

/// The vertex space split into degree-mass-balanced contiguous shards.
#[derive(Debug, Clone)]
pub struct PartitionSet {
    pub shards: Vec<Shard>,
    pub total_units: usize,
    pub total_items: usize,
    pub max_units_per_item: usize,
}

impl PartitionSet {
    /// Split `graph` into at most `max_shards` contiguous vertex ranges
    /// whose unit budgets are proportional (shard s ends once the running
    /// unit total reaches `(s+1)/n_shards` of the whole). The shard count
    /// is clamped to the item count so no worker is spawned with nothing
    /// to do; the last shard always extends to `n` so every vertex has a
    /// home range.
    pub fn build<G: GraphProbe>(graph: &G, max_shards: usize, max_units_per_item: usize) -> PartitionSet {
        assert!(max_shards >= 1);
        assert!(max_units_per_item >= 1);
        let n = graph.n();
        let unit_of: Vec<usize> = (0..n as u32).map(|v| root_units(graph, v)).collect();
        let total_units: usize = unit_of.iter().sum();
        let total_items: usize = unit_of.iter().map(|&u| u.div_ceil(max_units_per_item)).sum();
        let n_shards = max_shards.min(total_items.max(1));

        let mut shards = Vec::with_capacity(n_shards);
        let mut v = 0usize;
        let mut cum = 0usize;
        for s in 0..n_shards {
            let v_start = v as u32;
            let target = (s + 1) * total_units / n_shards;
            let last = s + 1 == n_shards;
            let mut items = Vec::new();
            let mut units = 0usize;
            while v < n && (last || cum < target) {
                push_root_items(&mut items, v as u32, unit_of[v], max_units_per_item);
                units += unit_of[v];
                cum += unit_of[v];
                v += 1;
            }
            shards.push(Shard { index: s, v_start, v_end: v as u32, units, items });
        }
        PartitionSet { shards, total_units, total_items, max_units_per_item }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Home vertex range per shard, in shard order.
    pub fn ranges(&self) -> Vec<(u32, u32)> {
        self.shards.iter().map(|s| (s.v_start, s.v_end)).collect()
    }

    /// Owner shard of vertex `v` — the shard whose home range contains
    /// it — or `None` past the vertex space. O(log shards) over the
    /// contiguous ascending ranges. The distribution planner leans on
    /// this being a total function over `[0, n)`: every root has exactly
    /// one owner, which is what makes cross-process merges loss-free.
    pub fn shard_of(&self, v: u32) -> Option<usize> {
        if self.shards.last().map_or(true, |s| v >= s.v_end) {
            return None;
        }
        Some(self.shards.partition_point(|s| s.v_end <= v))
    }

    /// All items concatenated in root-ascending order (the shared-cursor
    /// scheduler's queue).
    pub fn all_items(&self) -> Vec<WorkItem> {
        let mut out = Vec::with_capacity(self.total_items);
        for s in &self.shards {
            out.extend_from_slice(&s.items);
        }
        out
    }

    /// Per-shard item lists (the work-stealing scheduler's seed), cloned so
    /// a session can serve repeated queries from the cached partition.
    pub fn item_lists(&self) -> Vec<Vec<WorkItem>> {
        self.shards.iter().map(|s| s.items.clone()).collect()
    }

    /// Resident bytes of the cached work items across all shards — the
    /// partition term of the pool byte budget.
    pub fn memory_bytes(&self) -> usize {
        self.total_items * std::mem::size_of::<WorkItem>()
            + self.shards.len() * std::mem::size_of::<Shard>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn items_cover_all_units() {
        let g = generators::gnp_undirected(50, 0.2, 1);
        let items = build_items(&g, 4);
        assert_eq!(total_units(&items), g.und.m() / 2);
    }

    // -- work decomposition edge cases ------------------------------------

    #[test]
    fn unit_granularity_one() {
        let g = generators::gnp_undirected(40, 0.15, 7);
        let items = build_items(&g, 1);
        assert!(items.iter().all(|i| i.units() == 1));
        assert_eq!(total_units(&items), g.und.m() / 2);
    }

    #[test]
    fn empty_graph() {
        let g = crate::graph::csr::Graph::from_edges(0, &[], false);
        let items = build_items(&g, 64);
        assert!(items.is_empty());
        assert_eq!(total_units(&items), 0);
        let p = PartitionSet::build(&g, 8, 64);
        assert_eq!(p.n_shards(), 1);
        assert_eq!(p.total_units, 0);
        assert_eq!(p.shards[0].items.len(), 0);
    }

    #[test]
    fn isolated_vertices_contribute_no_items() {
        // only 0-1 connected; vertices 2..9 isolated
        let g = crate::graph::csr::Graph::from_edges(10, &[(0, 1)], false);
        let items = build_items(&g, 64);
        assert_eq!(items.len(), 1);
        assert_eq!(total_units(&items), 1);
        assert_eq!(total_units(&items), g.und.m() / 2);
        // every vertex still gets a home range
        let p = PartitionSet::build(&g, 4, 64);
        assert_eq!(p.shards.last().unwrap().v_end, 10);
    }

    #[test]
    fn hub_degree_not_multiple_of_chunk() {
        // star(100): hub has 99 proper neighbors; 99 = 6*16 + 3
        let g = generators::star(100);
        let items = build_items(&g, 16);
        let hub_items: Vec<_> = items.iter().filter(|i| i.root == 0).collect();
        assert_eq!(hub_items.len(), 99usize.div_ceil(16));
        assert_eq!(hub_items.last().unwrap().units(), 99 % 16);
        assert!(hub_items.iter().all(|i| i.units() <= 16));
        assert_eq!(total_units(&items), g.und.m() / 2);
        // leaves have no proper neighbors (their only neighbor is 0 < leaf)
        assert_eq!(items.iter().filter(|i| i.root != 0).count(), 0);
    }

    // -- partition balance ------------------------------------------------

    #[test]
    fn ranges_are_contiguous_and_cover_vertex_space() {
        let g = generators::gnp_undirected(123, 0.1, 9);
        let p = PartitionSet::build(&g, 5, 8);
        let mut expect = 0u32;
        for s in &p.shards {
            assert_eq!(s.v_start, expect);
            assert!(s.v_end >= s.v_start);
            expect = s.v_end;
        }
        assert_eq!(expect, g.n() as u32);
        let sum_units: usize = p.shards.iter().map(|s| s.units).sum();
        assert_eq!(sum_units, p.total_units);
        assert_eq!(p.total_units, g.und.m() / 2);
        let sum_items: usize = p.shards.iter().map(|s| s.items.len()).sum();
        assert_eq!(sum_items, p.total_items);
    }

    #[test]
    fn hub_gets_its_own_shard_under_degree_mass_balance() {
        // star(1000) relabeled or not: all 999 units sit on vertex 0, so
        // shard 0 is exactly {hub} and later shards hold only leaf ranges.
        let g = generators::star(1000);
        let p = PartitionSet::build(&g, 4, 16);
        assert_eq!(p.shards[0].v_start, 0);
        assert_eq!(p.shards[0].v_end, 1);
        assert_eq!(p.shards[0].units, 999);
        for s in &p.shards[1..] {
            assert_eq!(s.units, 0);
        }
    }

    #[test]
    fn unit_mass_roughly_balanced_on_random_graph() {
        let g = generators::gnp_undirected(400, 0.05, 21);
        let p = PartitionSet::build(&g, 8, 4);
        let total = p.total_units as f64;
        for s in &p.shards {
            // each shard within a factor of the ideal share plus one vertex
            // worth of slack (the boundary vertex can overshoot)
            let ideal = total / p.n_shards() as f64;
            assert!(
                (s.units as f64) < ideal + 400.0,
                "shard {} units {} vs ideal {ideal}",
                s.index,
                s.units
            );
        }
    }

    #[test]
    fn shard_count_clamped_to_item_count() {
        let g = crate::graph::csr::Graph::from_edges(3, &[(0, 1)], false);
        let p = PartitionSet::build(&g, 16, 64);
        assert_eq!(p.n_shards(), 1);
        assert_eq!(p.all_items().len(), 1);
    }

    #[test]
    fn shard_of_agrees_with_ranges() {
        let g = generators::gnp_undirected(123, 0.1, 9);
        let p = PartitionSet::build(&g, 5, 8);
        for v in 0..g.n() as u32 {
            let s = p.shard_of(v).unwrap();
            let (lo, hi) = p.ranges()[s];
            assert!((lo..hi).contains(&v), "vertex {v} mapped to shard {s} [{lo},{hi})");
        }
        assert_eq!(p.shard_of(g.n() as u32), None);
        assert_eq!(p.shard_of(u32::MAX), None);
        // a star's hub shard is [0,1): lookups skip the empty-range shards
        let star = generators::star(1000);
        let p = PartitionSet::build(&star, 4, 16);
        assert_eq!(p.shard_of(0), Some(0));
        assert!(p.shard_of(999).is_some());
    }

    #[test]
    fn all_items_matches_flat_build() {
        let g = generators::barabasi_albert(200, 3, 5);
        let p = PartitionSet::build(&g, 6, 8);
        assert_eq!(p.all_items(), build_items(&g, 8));
    }
}
