//! Prometheus text exposition (format 0.0.4) and the minimal HTTP/1.0
//! scrape endpoint behind `vdmc serve --metrics-addr`.
//!
//! [`render`] turns a registry snapshot into the canonical text format:
//! `# HELP`/`# TYPE` headers per family, one `name{labels} value` line
//! per series, and the `_bucket`/`_sum`/`_count` expansion (cumulative
//! `le` buckets, closed by `le="+Inf"`) for histograms.
//!
//! [`serve_exposition`] is a single-threaded accept loop shaped like
//! `service::serve_tcp` (nonblocking accept + short poll against a
//! shared shutdown flag), answering every `GET /metrics` with a freshly
//! rendered body. Scrapes are rare (seconds apart) and the body is one
//! `String`, so one thread handling connections serially is enough — no
//! per-client threads, no keep-alive, `Connection: close` always.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use super::metrics::{FamilySnapshot, ValueSnapshot};

/// Accept-poll interval while waiting for scrapers (mirrors the serve
/// loop's cadence).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Per-connection read/write timeout: a stalled scraper must not wedge
/// the exposition thread past this.
const CLIENT_TIMEOUT: Duration = Duration::from_millis(500);

/// Largest request head we will buffer before answering anyway.
const MAX_HEAD_BYTES: usize = 8192;

/// Render family snapshots as Prometheus text exposition format 0.0.4.
pub fn render(families: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    for fam in families {
        out.push_str("# HELP ");
        out.push_str(fam.name);
        out.push(' ');
        out.push_str(fam.help);
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(fam.name);
        out.push(' ');
        out.push_str(fam.kind.as_str());
        out.push('\n');
        for s in &fam.series {
            match &s.value {
                ValueSnapshot::Counter(v) => {
                    sample_line(&mut out, fam.name, "", &s.labels, None, &v.to_string());
                }
                ValueSnapshot::Gauge(v) => {
                    sample_line(&mut out, fam.name, "", &s.labels, None, &v.to_string());
                }
                ValueSnapshot::Histogram(h) => {
                    for &(le, cum) in &h.buckets {
                        sample_line(
                            &mut out,
                            fam.name,
                            "_bucket",
                            &s.labels,
                            Some(&format_f64(le)),
                            &cum.to_string(),
                        );
                    }
                    sample_line(
                        &mut out,
                        fam.name,
                        "_bucket",
                        &s.labels,
                        Some("+Inf"),
                        &h.count.to_string(),
                    );
                    let sum = format_f64(h.sum_secs);
                    sample_line(&mut out, fam.name, "_sum", &s.labels, None, &sum);
                    let count = h.count.to_string();
                    sample_line(&mut out, fam.name, "_count", &s.labels, None, &count);
                }
            }
        }
    }
    out
}

/// `name[suffix]{labels[,le="bound"]} value\n`
fn sample_line(
    out: &mut String,
    name: &str,
    suffix: &str,
    labels: &[(&'static str, String)],
    le: Option<&str>,
    value: &str,
) {
    out.push_str(name);
    out.push_str(suffix);
    if !labels.is_empty() || le.is_some() {
        out.push('{');
        let mut first = true;
        for (k, v) in labels {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(k);
            out.push_str("=\"");
            push_escaped(out, v);
            out.push('"');
        }
        if let Some(le) = le {
            if !first {
                out.push(',');
            }
            out.push_str("le=\"");
            out.push_str(le);
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

/// Escape a label value per the exposition format: backslash, quote,
/// newline.
fn push_escaped(out: &mut String, v: &str) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
}

/// Shortest faithful float form (`Display` round-trips f64); Prometheus
/// accepts plain decimal and exponent notation alike.
fn format_f64(v: f64) -> String {
    format!("{v}")
}

/// Serve `render()` output over HTTP/1.0 until `shutdown` flips.
/// Returns the number of successfully answered scrapes.
pub fn serve_exposition(
    listener: TcpListener,
    shutdown: &AtomicBool,
    render: &(dyn Fn() -> String + Sync),
) -> std::io::Result<u64> {
    listener.set_nonblocking(true)?;
    let mut served = 0u64;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                if answer_scrape(stream, render) {
                    served += 1;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(served)
}

/// Read one request head, answer, close. Returns true for a delivered
/// 200 body.
fn answer_scrape(mut stream: TcpStream, render: &(dyn Fn() -> String + Sync)) -> bool {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(CLIENT_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CLIENT_TIMEOUT));
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() >= MAX_HEAD_BYTES {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let request = String::from_utf8_lossy(&head);
    let path = request.split_whitespace().nth(1).unwrap_or("/");
    let (status, body) = if path == "/metrics" || path == "/" {
        ("200 OK", render())
    } else {
        ("404 Not Found", String::from("try /metrics\n"))
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes()).is_ok() && status.starts_with("200")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metrics::{MetricsRegistry, HIST_FINITE_BUCKETS};
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn fixture_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.counter_with("vdmc_requests_total", "Requests.", &[("op", "count")]).add(3);
        reg.gauge("vdmc_pool_entries", "Resident sessions.").set(2);
        reg.histogram("vdmc_request_seconds", "Latency.").record(0.004);
        reg
    }

    #[test]
    fn renders_help_type_and_samples() {
        let text = render(&fixture_registry().snapshot());
        assert!(text.contains("# HELP vdmc_pool_entries Resident sessions.\n"), "{text}");
        assert!(text.contains("# TYPE vdmc_pool_entries gauge\n"), "{text}");
        assert!(text.contains("vdmc_pool_entries 2\n"), "{text}");
        assert!(text.contains("# TYPE vdmc_requests_total counter\n"), "{text}");
        assert!(text.contains("vdmc_requests_total{op=\"count\"} 3\n"), "{text}");
    }

    #[test]
    fn histogram_expands_to_cumulative_buckets() {
        let text = render(&fixture_registry().snapshot());
        assert!(text.contains("# TYPE vdmc_request_seconds histogram\n"), "{text}");
        let buckets: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("vdmc_request_seconds_bucket"))
            .collect();
        assert_eq!(buckets.len(), HIST_FINITE_BUCKETS + 1, "finite buckets + +Inf");
        assert!(buckets.last().unwrap().contains("le=\"+Inf\"} 1"), "{buckets:?}");
        // cumulative counts never decrease
        let counts: Vec<u64> =
            buckets.iter().map(|l| l.rsplit(' ').next().unwrap().parse().unwrap()).collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        assert!(text.contains("vdmc_request_seconds_count 1\n"), "{text}");
        assert!(text.contains("vdmc_request_seconds_sum 0.004"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut out = String::new();
        sample_line(&mut out, "m", "", &[("p", "a\"b\\c\nd".to_string())], None, "1");
        assert_eq!(out, "m{p=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn exposition_answers_http_scrapes() {
        let reg = Arc::new(fixture_registry());
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let shutdown = Arc::new(AtomicBool::new(false));
        let handle = {
            let shutdown = shutdown.clone();
            let reg = reg.clone();
            std::thread::spawn(move || {
                serve_exposition(listener, &shutdown, &move || render(&reg.snapshot()))
            })
        };

        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").expect("request");
        let mut response = String::new();
        stream.read_to_string(&mut response).expect("response");
        assert!(response.starts_with("HTTP/1.0 200 OK\r\n"), "{response}");
        assert!(response.contains("Content-Type: text/plain; version=0.0.4"), "{response}");
        let body = response.split("\r\n\r\n").nth(1).expect("body");
        let len: usize = response
            .lines()
            .find(|l| l.starts_with("Content-Length: "))
            .and_then(|l| l.trim_start_matches("Content-Length: ").parse().ok())
            .expect("content length");
        assert_eq!(body.len(), len, "Content-Length must match the body");
        assert!(body.contains("vdmc_requests_total{op=\"count\"} 3\n"), "{body}");

        let mut stream = TcpStream::connect(addr).expect("connect 404");
        stream.write_all(b"GET /nope HTTP/1.0\r\n\r\n").expect("request");
        let mut response = String::new();
        stream.read_to_string(&mut response).expect("response");
        assert!(response.starts_with("HTTP/1.0 404"), "{response}");

        shutdown.store(true, Ordering::SeqCst);
        let served = handle.join().expect("join").expect("serve ok");
        assert_eq!(served, 1, "one 200 scrape answered");
    }
}
