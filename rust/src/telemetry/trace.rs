//! Request-scoped tracing and structured logging.
//!
//! One request = one **root span**: the service opens it with
//! [`start_root`], carrying the trace id (client-supplied `"trace"` wire
//! field or generated) and, when telemetry is enabled, the service's
//! metrics registry. The context lives in a thread local, so engine code
//! deep inside `Session`/`SessionSnapshot` can attach child **phase**
//! records ([`record_phase`]/[`time_phase`]: pin, setup, schedule,
//! enumerate, merge, commit) without any signature threading — a session
//! used standalone, outside any span, pays a single thread-local check
//! and records nothing.
//!
//! Finished root spans become [`TraceRecord`]s in a bounded in-memory
//! [`TraceBuffer`] (newest wins); requests slower than the service's
//! threshold additionally emit one structured slow-query line on stderr
//! through [`log`], the JSON-lines logger gated by the process-wide
//! [`LogLevel`].

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use super::metrics::MetricsRegistry;
use crate::util::json::Json;

/// Histogram family phase durations land in (label: `phase`).
pub const PHASE_SECONDS: &str = "vdmc_phase_seconds";
const PHASE_HELP: &str = "Engine phase duration within one request, by phase.";

struct ActiveTrace {
    trace_id: String,
    registry: Option<Arc<MetricsRegistry>>,
    phases: Vec<(&'static str, f64)>,
}

thread_local! {
    static ACTIVE: RefCell<Option<ActiveTrace>> = const { RefCell::new(None) };
}

/// Guard for one root span. Close it with [`RootSpan::finish`] to
/// collect the recorded phases; dropping it without finishing (error
/// unwind) just restores the previous context.
pub struct RootSpan {
    prev: Option<ActiveTrace>,
    start: Instant,
    finished: bool,
}

/// Open a root span on this thread, shadowing any active one until the
/// guard closes. `registry` routes phase records into the
/// [`PHASE_SECONDS`] histogram as well; `None` keeps them span-only.
pub fn start_root(trace_id: String, registry: Option<Arc<MetricsRegistry>>) -> RootSpan {
    let prev = ACTIVE.with(|a| {
        a.borrow_mut().replace(ActiveTrace { trace_id, registry, phases: Vec::new() })
    });
    RootSpan { prev, start: Instant::now(), finished: false }
}

impl RootSpan {
    /// Close the span: restore the shadowed context and return the
    /// recorded `(phase, secs)` pairs plus total elapsed seconds.
    pub fn finish(mut self) -> (Vec<(&'static str, f64)>, f64) {
        self.finished = true;
        let cur = ACTIVE.with(|a| std::mem::replace(&mut *a.borrow_mut(), self.prev.take()));
        let phases = cur.map(|t| t.phases).unwrap_or_default();
        (phases, self.start.elapsed().as_secs_f64())
    }
}

impl Drop for RootSpan {
    fn drop(&mut self) {
        if !self.finished {
            ACTIVE.with(|a| *a.borrow_mut() = self.prev.take());
        }
    }
}

/// Attach one completed phase to the active root span (and its phase
/// histogram, when the span carries a registry). No-op outside a span.
pub fn record_phase(name: &'static str, secs: f64) {
    ACTIVE.with(|a| {
        if let Some(t) = a.borrow_mut().as_mut() {
            t.phases.push((name, secs));
            if let Some(reg) = &t.registry {
                reg.histogram_with(PHASE_SECONDS, PHASE_HELP, &[("phase", name)]).record(secs);
            }
        }
    });
}

/// Run `f`, timing it as a phase when a root span is active; outside a
/// span `f` runs untimed (not even an `Instant` read).
pub fn time_phase<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    let active = ACTIVE.with(|a| a.borrow().is_some());
    if !active {
        return f();
    }
    let t0 = Instant::now();
    let out = f();
    record_phase(name, t0.elapsed().as_secs_f64());
    out
}

/// Run `f` against the active span's metrics registry, when both exist —
/// how engine code records counters without holding a registry handle.
pub fn with_registry(f: impl FnOnce(&MetricsRegistry)) {
    let reg = ACTIVE.with(|a| a.borrow().as_ref().and_then(|t| t.registry.clone()));
    if let Some(reg) = reg {
        f(&reg);
    }
}

/// Trace id of the active root span on this thread.
pub fn current_trace_id() -> Option<String> {
    ACTIVE.with(|a| a.borrow().as_ref().map(|t| t.trace_id.clone()))
}

static TRACE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Generate a trace id for a request that did not supply one: process
/// id + wall-clock nanos + a process-wide sequence number.
pub fn gen_trace_id() -> String {
    // relaxed: uniqueness needs only the RMW total order on the
    // sequence counter; nothing else is published with an id.
    let seq = TRACE_SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| u64::from(d.subsec_nanos()))
        .unwrap_or(0);
    format!("t{:x}-{:x}-{seq:x}", std::process::id(), nanos)
}

/// One finished root span.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub trace_id: String,
    pub op: String,
    pub graph: Option<String>,
    pub total_secs: f64,
    /// Child phases in completion order; phases can nest (schedule and
    /// merge run inside enumerate's window), so they need not sum to
    /// `total_secs`.
    pub phases: Vec<(&'static str, f64)>,
}

impl TraceRecord {
    /// Structured form for slow-query logging.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("trace", self.trace_id.as_str()).set("op", self.op.as_str());
        if let Some(g) = &self.graph {
            j.set("graph", g.as_str());
        }
        j.set("total_secs", self.total_secs);
        let mut phases = Json::obj();
        for (name, secs) in &self.phases {
            // repeated phases (one per re-enumerated edge, say) fold
            // into one summed entry
            let prior = phases.get(name).and_then(Json::as_f64).unwrap_or(0.0);
            phases.set(name, prior + secs);
        }
        j.set("phases", phases);
        j
    }
}

/// Bounded FIFO of the most recent finished traces.
pub struct TraceBuffer {
    cap: usize,
    records: Mutex<VecDeque<TraceRecord>>,
}

impl TraceBuffer {
    pub fn new(cap: usize) -> TraceBuffer {
        TraceBuffer { cap: cap.max(1), records: Mutex::new(VecDeque::new()) }
    }

    pub fn push(&self, rec: TraceRecord) {
        let mut records = self.records.lock().expect("trace buffer poisoned");
        if records.len() == self.cap {
            records.pop_front();
        }
        records.push_back(rec);
    }

    pub fn len(&self) -> usize {
        self.records.lock().expect("trace buffer poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The most recent `n` traces, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceRecord> {
        let records = self.records.lock().expect("trace buffer poisoned");
        records.iter().skip(records.len().saturating_sub(n)).cloned().collect()
    }
}

// ---------------------------------------------------------------- logging

/// Stderr log verbosity, most to least quiet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Off = 0,
    Error = 1,
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s {
            "off" => Some(LogLevel::Off),
            "error" => Some(LogLevel::Error),
            "info" => Some(LogLevel::Info),
            "debug" => Some(LogLevel::Debug),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            LogLevel::Off => "off",
            LogLevel::Error => "error",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }
}

/// Process-wide level; Info by default so slow-query lines are visible
/// without flags.
static LOG_LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Info as u8);

pub fn set_log_level(level: LogLevel) {
    // relaxed: standalone configuration flag — readers act on the level
    // value alone, and a briefly stale read only delays a log line.
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log_level() -> LogLevel {
    // relaxed: see set_log_level — value-only flag, staleness harmless.
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => LogLevel::Off,
        1 => LogLevel::Error,
        3 => LogLevel::Debug,
        _ => LogLevel::Info,
    }
}

/// Emit one structured JSON log line on stderr when `level` is enabled:
/// `{"level":...,"msg":...,"target":...,"ts":...}` plus `fields`.
pub fn log(level: LogLevel, target: &str, msg: &str, fields: &[(&str, Json)]) {
    if level == LogLevel::Off || level > log_level() {
        return;
    }
    let ts = SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0);
    let mut j = Json::obj();
    j.set("ts", ts).set("level", level.as_str()).set("target", target).set("msg", msg);
    for (k, v) in fields {
        j.set(k, v.clone());
    }
    eprintln!("{}", j.to_string_compact());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_outside_a_span_are_dropped() {
        record_phase("setup", 0.5);
        let span = start_root("t1".into(), None);
        let (phases, _) = span.finish();
        assert!(phases.is_empty(), "pre-span phase leaked in: {phases:?}");
    }

    #[test]
    fn root_span_collects_phases_and_restores_context() {
        assert_eq!(current_trace_id(), None);
        let span = start_root("outer".into(), None);
        record_phase("pin", 0.001);
        {
            let inner = start_root("inner".into(), None);
            assert_eq!(current_trace_id().as_deref(), Some("inner"));
            record_phase("setup", 0.002);
            let (phases, _) = inner.finish();
            assert_eq!(phases, vec![("setup", 0.002)]);
        }
        assert_eq!(current_trace_id().as_deref(), Some("outer"));
        let out = time_phase("enumerate", || 41 + 1);
        assert_eq!(out, 42);
        let (phases, total) = span.finish();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0], ("pin", 0.001));
        assert_eq!(phases[1].0, "enumerate");
        assert!(total >= 0.0);
        assert_eq!(current_trace_id(), None);
    }

    #[test]
    fn phase_records_feed_the_span_registry() {
        let reg = Arc::new(MetricsRegistry::new());
        let span = start_root("t".into(), Some(reg.clone()));
        record_phase("merge", 0.004);
        record_phase("merge", 0.008);
        drop(span); // drop-without-finish must still restore the TLS
        assert_eq!(current_trace_id(), None);
        let h = reg.histogram_with(PHASE_SECONDS, "", &[("phase", "merge")]);
        assert_eq!(h.count(), 2);
        assert!((h.sum_secs() - 0.012).abs() < 1e-9);
    }

    #[test]
    fn trace_buffer_is_bounded_newest_wins() {
        let buf = TraceBuffer::new(2);
        for i in 0..5 {
            buf.push(TraceRecord {
                trace_id: format!("t{i}"),
                op: "count".into(),
                graph: None,
                total_secs: 0.1,
                phases: vec![],
            });
        }
        assert_eq!(buf.len(), 2);
        let recent = buf.recent(8);
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].trace_id, "t3");
        assert_eq!(recent[1].trace_id, "t4");
    }

    #[test]
    fn trace_record_json_folds_repeated_phases() {
        let rec = TraceRecord {
            trace_id: "abc".into(),
            op: "apply_edges".into(),
            graph: Some("g".into()),
            total_secs: 1.5,
            phases: vec![("commit", 0.25), ("commit", 0.25)],
        };
        let s = rec.to_json().to_string_compact();
        assert!(s.contains("\"trace\":\"abc\""), "{s}");
        assert!(s.contains("\"commit\":0.5"), "{s}");
    }

    #[test]
    fn log_level_parses_and_orders() {
        assert_eq!(LogLevel::parse("debug"), Some(LogLevel::Debug));
        assert_eq!(LogLevel::parse("verbose"), None);
        assert!(LogLevel::Off < LogLevel::Error && LogLevel::Error < LogLevel::Info);
        assert_eq!(LogLevel::parse(LogLevel::Info.as_str()), Some(LogLevel::Info));
    }

    #[test]
    fn gen_trace_ids_are_unique() {
        let a = gen_trace_id();
        let b = gen_trace_id();
        assert_ne!(a, b);
        assert!(a.starts_with('t'));
    }
}
