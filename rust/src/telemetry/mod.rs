//! Cross-cutting telemetry: the metrics registry, request tracing spans,
//! structured stderr logging and Prometheus exposition.
//!
//! Std-only (like everything in the vendored offline build) and split
//! in three layers, each usable alone:
//!
//! - [`metrics`] — lock-cheap [`Counter`]s/[`Gauge`]s and
//!   exponential-bucket [`Histogram`]s behind a [`MetricsRegistry`]:
//!   name + label lookup under one short mutex hold, relaxed atomics on
//!   the hot path. Replaces the service pool's former 1024-sample
//!   latency rings.
//! - [`trace`] — a thread-local root span per request ([`start_root`])
//!   that engine code decorates with child phase records
//!   ([`record_phase`]: pin, setup, schedule, enumerate, merge, commit)
//!   without signature changes; finished spans land in a bounded
//!   [`TraceBuffer`] and slow ones in a structured stderr line.
//! - [`prometheus`] — text exposition (format 0.0.4) of a registry
//!   snapshot, plus the single-threaded HTTP/1.0 scrape loop behind
//!   `vdmc serve --metrics-addr`.
//!
//! The service layer ties them together: `VdmcService` owns one
//! registry, opens the root span in `handle_traced`, and
//! `Request::Metrics` / the `--metrics-addr` endpoint render the same
//! snapshot. A `Session` used standalone (no service, no span) pays one
//! thread-local check per phase and records nothing.

pub mod metrics;
pub mod prometheus;
pub mod trace;

pub use metrics::{
    Counter, FamilySnapshot, Gauge, Histogram, HistogramSnapshot, MetricKind, MetricsRegistry,
    SeriesSnapshot, ValueSnapshot,
};
pub use prometheus::{render, serve_exposition};
pub use trace::{
    current_trace_id, gen_trace_id, log, log_level, record_phase, set_log_level, start_root,
    time_phase, with_registry, LogLevel, RootSpan, TraceBuffer, TraceRecord,
};
