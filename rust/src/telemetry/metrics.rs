//! Lock-cheap process metrics: counters, gauges and exponential-bucket
//! histograms behind a [`MetricsRegistry`].
//!
//! The registry is the *naming* layer: [`MetricsRegistry::counter_with`]
//! and friends look up (or create) a family by name and a series by
//! label set under one short mutex hold, and hand back an `Arc` to the
//! instrument. The hot path — [`Counter::inc`], [`Gauge::set`],
//! [`Histogram::record`] — is pure relaxed atomics on that shared
//! handle: no lock, no allocation, safe to call from any worker thread.
//!
//! Histograms use fixed exponential buckets (first bound
//! [`HIST_FIRST_BOUND`] seconds, growth [`HIST_GROWTH`]×, covering
//! 1 µs .. ~134 s), so p50/p99 come from a cumulative bucket walk with
//! linear interpolation — bounded error of one bucket width, constant
//! memory, and exact merge across threads. This replaces the service
//! pool's old 1024-sample rings, which forgot history beyond the window
//! and sorted on every read.
//!
//! Reads ([`MetricsRegistry::snapshot`]) are loosely consistent with
//! concurrent writers: a histogram scraped mid-`record` may briefly show
//! `count` ahead of its buckets. That is fine for monitoring and never
//! produces negative rates.

use crate::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

/// Lowest histogram bucket upper bound, in seconds (1 µs).
pub const HIST_FIRST_BOUND: f64 = 1e-6;
/// Multiplicative growth between consecutive bucket bounds.
pub const HIST_GROWTH: f64 = 2.0;
/// Finite buckets; the last bound is `1e-6 * 2^27` ≈ 134 s, everything
/// above lands in the implicit overflow (+Inf) bucket.
pub const HIST_FINITE_BUCKETS: usize = 28;

/// Upper bound (inclusive) of finite bucket `i`, in seconds.
pub fn bucket_bound(i: usize) -> f64 {
    HIST_FIRST_BOUND * HIST_GROWTH.powi(i as i32)
}

/// Monotonically increasing counter (relaxed atomic u64).
#[cfg_attr(not(loom), derive(Debug))]
pub struct Counter(AtomicU64);

// hand-written (not derived): loom's atomics implement neither Default
// nor (reliably) Debug
impl Default for Counter {
    fn default() -> Counter {
        Counter(AtomicU64::new(0))
    }
}

#[cfg(loom)]
impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Counter")
    }
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // relaxed: pure tally — the RMW total order on the counter makes
        // concurrent adds exact, and readers consume the value alone, so
        // no other memory needs to be published with it.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the absolute value. For totals whose source of truth
    /// lives elsewhere (the session pool's mutex-guarded tallies) and is
    /// mirrored into the registry at scrape time; incrementing paths use
    /// [`Counter::inc`]/[`Counter::add`] instead. Mixing both on one
    /// counter would lose increments.
    pub fn store(&self, v: u64) {
        // relaxed: absolute mirror of a mutex-guarded source of truth;
        // scrapes tolerate loose ordering (module docs).
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // relaxed: monitoring read of an independent value.
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value that can go up and down (relaxed atomic i64).
#[cfg_attr(not(loom), derive(Debug))]
pub struct Gauge(AtomicI64);

// hand-written (not derived): loom's atomics implement neither Default
// nor (reliably) Debug
impl Default for Gauge {
    fn default() -> Gauge {
        Gauge(AtomicI64::new(0))
    }
}

#[cfg(loom)]
impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Gauge")
    }
}

impl Gauge {
    pub fn set(&self, v: i64) {
        // relaxed: point-in-time monitoring value, no data published
        // alongside it.
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        // relaxed: tally — RMW total order keeps concurrent deltas exact.
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn get(&self) -> i64 {
        // relaxed: monitoring read of an independent value.
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed exponential-bucket latency histogram (seconds).
#[cfg_attr(not(loom), derive(Debug))]
pub struct Histogram {
    /// Per-bucket (non-cumulative) sample counts; index
    /// [`HIST_FINITE_BUCKETS`] is the overflow (+Inf) bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

/// Smallest bucket index whose bound is >= `secs` (le semantics).
fn bucket_index(secs: f64) -> usize {
    let mut idx = 0;
    let mut bound = HIST_FIRST_BOUND;
    while idx < HIST_FINITE_BUCKETS && secs > bound {
        idx += 1;
        bound *= HIST_GROWTH;
    }
    idx
}

#[cfg(loom)]
impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Histogram")
    }
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: (0..=HIST_FINITE_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    /// Record one observation in seconds. Non-finite or negative values
    /// are clamped to 0 (lowest bucket) rather than dropped, so `count`
    /// always matches the number of `record` calls.
    pub fn record(&self, secs: f64) {
        let v = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        // relaxed: three independent tallies; each RMW is exact on its
        // own location, and the module-documented contract is that a
        // concurrent snapshot may see count ahead of the buckets — never
        // a lost sample, never a negative rate.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add((v * 1e9).round() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        // relaxed: monitoring read (loosely consistent, module docs).
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        // relaxed: monitoring read (loosely consistent, module docs).
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_secs() / n as f64
        }
    }

    /// Estimated q-quantile; see [`HistogramSnapshot::quantile`].
    pub fn quantile(&self, q: f64) -> f64 {
        self.snapshot().quantile(q)
    }

    /// Consistent-enough copy for rendering and percentile math:
    /// cumulative finite buckets plus total count and sum.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut cum = 0u64;
        let mut buckets = Vec::with_capacity(HIST_FINITE_BUCKETS);
        // relaxed: loosely-consistent scrape (module docs) — the
        // snapshot's count is rebuilt from the bucket reads themselves,
        // so quantile math is internally consistent even mid-record.
        for (i, b) in self.buckets.iter().take(HIST_FINITE_BUCKETS).enumerate() {
            cum += b.load(Ordering::Relaxed);
            buckets.push((bucket_bound(i), cum));
        }
        // the +Inf bucket is implicit: cumulative count there == count
        let overflow = self.buckets[HIST_FINITE_BUCKETS].load(Ordering::Relaxed);
        HistogramSnapshot { buckets, count: cum + overflow, sum_secs: self.sum_secs() }
    }
}

/// Frozen histogram state: `(upper_bound_secs, cumulative_count)` per
/// finite bucket; `count` additionally includes the overflow bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub buckets: Vec<(f64, u64)>,
    pub count: u64,
    pub sum_secs: f64,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_secs / self.count as f64
        }
    }

    /// Estimate the q-quantile by walking the cumulative buckets and
    /// interpolating linearly inside the winning bucket. The estimate is
    /// always within the true quantile's bucket, i.e. off by at most one
    /// [`HIST_GROWTH`] factor; overflow-bucket quantiles clamp to the
    /// last finite bound.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut prev_cum = 0u64;
        let mut prev_bound = 0.0;
        for &(le, cum) in &self.buckets {
            if cum >= target {
                let in_bucket = cum - prev_cum;
                let frac = (target - prev_cum) as f64 / in_bucket as f64;
                return prev_bound + (le - prev_bound) * frac;
            }
            prev_cum = cum;
            prev_bound = le;
        }
        self.buckets.last().map(|&(le, _)| le).unwrap_or(0.0)
    }
}

/// What a family's series hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    /// Prometheus `# TYPE` keyword.
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Family {
    name: &'static str,
    help: &'static str,
    kind: MetricKind,
    series: Vec<(Vec<(&'static str, String)>, Metric)>,
}

/// One series' frozen value.
#[derive(Debug, Clone, PartialEq)]
pub enum ValueSnapshot {
    Counter(u64),
    Gauge(i64),
    Histogram(HistogramSnapshot),
}

/// One labeled series inside a [`FamilySnapshot`].
#[derive(Debug, Clone)]
pub struct SeriesSnapshot {
    pub labels: Vec<(&'static str, String)>,
    pub value: ValueSnapshot,
}

/// One metric family: name, help, kind and every labeled series.
#[derive(Debug, Clone)]
pub struct FamilySnapshot {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: MetricKind,
    pub series: Vec<SeriesSnapshot>,
}

fn labels_match(ls: &[(&'static str, String)], labels: &[(&'static str, &str)]) -> bool {
    ls.len() == labels.len()
        && ls.iter().zip(labels).all(|((ak, av), (bk, bv))| ak == bk && av == bv)
}

/// Process-wide registry of metric families. Cheap to share
/// (`Arc<MetricsRegistry>`); every service owns exactly one so parallel
/// `cargo test` services never pollute each other's counts.
pub struct MetricsRegistry {
    families: Mutex<Vec<Family>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { families: Mutex::new(Vec::new()) }
    }

    /// Unlabeled counter (the family's single series).
    pub fn counter(&self, name: &'static str, help: &'static str) -> Arc<Counter> {
        self.counter_with(name, help, &[])
    }

    /// Look up or create a counter series. Panics when `name` already
    /// exists with a different kind — a programming error, not a runtime
    /// condition.
    pub fn counter_with(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Counter> {
        match self.series(name, help, MetricKind::Counter, labels) {
            Metric::Counter(c) => c,
            _ => unreachable!("kind checked by series()"),
        }
    }

    /// Unlabeled gauge (the family's single series).
    pub fn gauge(&self, name: &'static str, help: &'static str) -> Arc<Gauge> {
        self.gauge_with(name, help, &[])
    }

    /// Look up or create a gauge series.
    pub fn gauge_with(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Gauge> {
        match self.series(name, help, MetricKind::Gauge, labels) {
            Metric::Gauge(g) => g,
            _ => unreachable!("kind checked by series()"),
        }
    }

    /// Unlabeled histogram (the family's single series).
    pub fn histogram(&self, name: &'static str, help: &'static str) -> Arc<Histogram> {
        self.histogram_with(name, help, &[])
    }

    /// Look up or create a histogram series.
    pub fn histogram_with(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<Histogram> {
        match self.series(name, help, MetricKind::Histogram, labels) {
            Metric::Histogram(h) => h,
            _ => unreachable!("kind checked by series()"),
        }
    }

    fn series(
        &self,
        name: &'static str,
        help: &'static str,
        kind: MetricKind,
        labels: &[(&'static str, &str)],
    ) -> Metric {
        let mut fams = self.families.lock().expect("metrics registry poisoned");
        let fam = match fams.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert!(
                    f.kind == kind,
                    "metric {name:?} registered as {:?}, requested as {kind:?}",
                    f.kind
                );
                f
            }
            None => {
                fams.push(Family { name, help, kind, series: Vec::new() });
                fams.last_mut().expect("family just pushed")
            }
        };
        if let Some((_, m)) = fam.series.iter().find(|(ls, _)| labels_match(ls, labels)) {
            return m.clone();
        }
        let metric = match kind {
            MetricKind::Counter => Metric::Counter(Arc::new(Counter::default())),
            MetricKind::Gauge => Metric::Gauge(Arc::new(Gauge::default())),
            MetricKind::Histogram => Metric::Histogram(Arc::new(Histogram::new())),
        };
        fam.series.push((
            labels.iter().map(|(k, v)| (*k, (*v).to_string())).collect(),
            metric.clone(),
        ));
        metric
    }

    /// Freeze every family for exposition: families sorted by name,
    /// series by label values, so rendered output is deterministic.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let fams = self.families.lock().expect("metrics registry poisoned");
        let mut out: Vec<FamilySnapshot> = fams
            .iter()
            .map(|f| {
                let mut series: Vec<SeriesSnapshot> = f
                    .series
                    .iter()
                    .map(|(labels, m)| SeriesSnapshot {
                        labels: labels.clone(),
                        value: match m {
                            Metric::Counter(c) => ValueSnapshot::Counter(c.get()),
                            Metric::Gauge(g) => ValueSnapshot::Gauge(g.get()),
                            Metric::Histogram(h) => ValueSnapshot::Histogram(h.snapshot()),
                        },
                    })
                    .collect();
                series.sort_by(|a, b| a.labels.cmp(&b.labels));
                FamilySnapshot { name: f.name, help: f.help, kind: f.kind, series }
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(b.name));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::summarize;

    #[test]
    fn counter_and_gauge_basics() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("t_total", "help");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.store(2);
        assert_eq!(c.get(), 2);
        let g = reg.gauge("t_gauge", "help");
        g.set(7);
        g.dec();
        g.add(-2);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn registry_reuses_series_by_name_and_labels() {
        let reg = MetricsRegistry::new();
        let a = reg.counter_with("reqs_total", "h", &[("op", "count")]);
        let b = reg.counter_with("reqs_total", "h", &[("op", "count")]);
        let c = reg.counter_with("reqs_total", "h", &[("op", "stats")]);
        assert!(Arc::ptr_eq(&a, &b), "same labels must share the series");
        assert!(!Arc::ptr_eq(&a, &c), "different labels must not");
        a.add(3);
        assert_eq!(b.get(), 3);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].series.len(), 2);
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        let _ = reg.counter("x_total", "h");
        let _ = reg.gauge("x_total", "h");
    }

    #[test]
    fn histogram_tracks_the_stats_oracle() {
        // 1..=100 ms uniform — the same fixture the old latency rings
        // used. Mean/sum must match util::stats exactly (the histogram
        // keeps an exact nanosecond sum); quantile estimates must land
        // inside the true quantile's bucket (one HIST_GROWTH factor).
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat_seconds", "h");
        let samples: Vec<f64> = (1..=100).map(|ms| ms as f64 / 1000.0).collect();
        for &s in &samples {
            h.record(s);
        }
        let oracle = summarize(&samples);
        assert_eq!(h.count(), 100);
        assert!((h.sum_secs() - samples.iter().sum::<f64>()).abs() < 1e-6);
        assert!((h.mean() - oracle.mean).abs() < 1e-6, "{} vs {}", h.mean(), oracle.mean);

        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        // true p50 = 0.050, p99 = 0.099 for this fixture
        assert!(p50 <= p99, "quantiles must be monotone: {p50} > {p99}");
        for (est, truth) in [(p50, 0.050), (p99, 0.099)] {
            assert!(
                est >= truth / HIST_GROWTH && est <= truth * HIST_GROWTH,
                "estimate {est} not within one bucket of {truth}"
            );
        }
        assert!(h.quantile(0.0) > 0.0);
        assert!(h.quantile(1.0) >= p99);
    }

    #[test]
    fn histogram_edge_values_stay_counted() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("edge_seconds", "h");
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(1e9); // overflow bucket
        assert_eq!(h.count(), 4);
        let snap = h.snapshot();
        assert_eq!(snap.buckets.len(), HIST_FINITE_BUCKETS);
        // three clamped-to-zero samples in the first bucket
        assert_eq!(snap.buckets[0].1, 3);
        // the overflow sample is in count but not in any finite bucket
        assert_eq!(snap.buckets.last().unwrap().1, 3);
        // an all-overflow quantile clamps to the last finite bound
        assert_eq!(h.quantile(0.999), bucket_bound(HIST_FINITE_BUCKETS - 1));
    }

    #[test]
    fn bucket_index_is_le_consistent() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(HIST_FIRST_BOUND), 0);
        assert_eq!(bucket_index(HIST_FIRST_BOUND * 1.01), 1);
        assert_eq!(bucket_index(f64::MAX), HIST_FINITE_BUCKETS);
    }

    #[test]
    fn counters_are_exact_under_racing_threads() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("race_total", "h");
        let h = reg.histogram("race_seconds", "h");
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for i in 0..10_000u64 {
                        c.inc();
                        if i % 100 == 0 {
                            h.record(0.001);
                        }
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
        assert_eq!(h.count(), 800);
    }
}
