//! Emission-pipeline overhead bench: Count vs Instances vs Sample vs
//! TopVertices on the same graph and session, one JSON row per output
//! kind plus overhead-ratio rows — what the EnumSink generalization
//! costs *per emitted instance* relative to pure counting.
//!
//! Expectations (asserted where exact, printed where statistical):
//!   - every output reports the identical class histogram
//!     (`per_class_totals`), so the rows measure overhead, not work;
//!   - Count is the floor; TopVertices ≈ Sharded counting; Sample pays
//!     one instance hash per event; Instances pays buffering + one
//!     mutex drain per 256 events until the limit, then counting only.
//!
//! CI's bench-smoke job runs this shrunk (`-- --n 4000`) and archives
//! the rows as the `BENCH_sinks.json` artifact (schema seeded at the
//! repo root).

use std::time::Instant;

use vdmc::engine::{MotifQuery, Output, QueryOutput, Session, SessionConfig};
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::json::Json;

struct Opts {
    n: usize,
    ba_m: usize,
    seed: u64,
    workers: usize,
    k: usize,
    limit: usize,
    per_class: usize,
}

fn parse_opts() -> Opts {
    let mut opts =
        Opts { n: 12_000, ba_m: 3, seed: 42, workers: 4, k: 4, limit: 100_000, per_class: 64 };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("{} needs a value", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--n" => opts.n = take(&mut i).parse().expect("--n"),
            "--ba" => opts.ba_m = take(&mut i).parse().expect("--ba"),
            "--seed" => opts.seed = take(&mut i).parse().expect("--seed"),
            "--workers" => opts.workers = take(&mut i).parse().expect("--workers"),
            "--k" => opts.k = take(&mut i).parse().expect("--k"),
            "--limit" => opts.limit = take(&mut i).parse().expect("--limit"),
            "--per-class" => opts.per_class = take(&mut i).parse().expect("--per-class"),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

fn main() {
    let opts = parse_opts();
    let g = generators::barabasi_albert(opts.n, opts.ba_m, opts.seed);
    println!(
        "# sink overhead on BA({}, {}) seed {}: n={} m={}, k={}, {} workers",
        opts.n,
        opts.ba_m,
        opts.seed,
        g.n(),
        g.m(),
        opts.k,
        opts.workers,
    );
    let session =
        Session::load_with(&g, &SessionConfig { workers: opts.workers, ..Default::default() });
    let size = MotifSize::from_k(opts.k).expect("--k must be 3 or 4");
    let base = MotifQuery { size, direction: Direction::Undirected, ..Default::default() };

    let outputs: Vec<(&str, Output)> = vec![
        ("counts", Output::Counts),
        ("instances", Output::Instances { limit: opts.limit }),
        ("sample", Output::Sample { per_class: opts.per_class, seed: opts.seed }),
        ("top-vertices", Output::TopVertices { k: 10 }),
    ];

    let mut histogram: Option<Vec<u64>> = None;
    let mut secs_of: Vec<(String, f64)> = Vec::new();
    for (label, output) in outputs {
        let q = MotifQuery { output, ..base.clone() };
        // warm-up, then the measured run (cached setup for every row)
        let _ = session.query(&q).unwrap();
        let t0 = Instant::now();
        let (result, report) = session.query_with_report(&q).unwrap();
        let secs = t0.elapsed().as_secs_f64();

        // every output must report the identical class histogram — the
        // rows measure sink overhead, never different work
        let want = histogram.get_or_insert_with(|| report.per_class_totals.clone());
        assert_eq!(&report.per_class_totals, want, "{label} changed the histogram");

        let mut j = Json::obj();
        j.set("bench", "sink")
            .set("output", label)
            .set("k", opts.k)
            .set("workers", session.workers())
            .set("n", g.n())
            .set("m", g.m())
            .set("instances", report.total_instances)
            .set("secs", secs)
            .set("ns_per_instance", secs * 1e9 / report.total_instances.max(1) as f64);
        match &result {
            QueryOutput::Instances(list) => {
                j.set("materialized", list.instances.len()).set("truncated", list.truncated);
            }
            QueryOutput::Sample(s) => {
                j.set("reservoirs", s.classes.iter().filter(|c| c.seen > 0).count())
                    .set("per_class", s.per_class);
            }
            QueryOutput::TopVertices(t) => {
                j.set("top", t.top_k);
            }
            QueryOutput::Counts(_) => {}
        }
        println!("{}", j.to_string_compact());
        secs_of.push((label.to_string(), secs));
    }

    let count_secs = secs_of[0].1.max(1e-12);
    for (label, secs) in &secs_of[1..] {
        let mut j = Json::obj();
        j.set("bench", "sink_overhead")
            .set("output", label.as_str())
            .set("vs_counts", secs / count_secs);
        println!("{}", j.to_string_compact());
    }
    println!("# expectation: vs_counts stays O(1) — the event pipeline adds per-instance work");
    println!("# (a hash for sample, buffered pushes for instances), never an extra graph pass.");
}
