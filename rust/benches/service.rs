//! Service-layer benchmark: pool hit/miss behavior and per-request
//! latency of the `VdmcService` façade under interleaved multi-graph
//! traffic — the serving-path numbers `BENCH_service.json` tracks.
//!
//! One JSON row per line on stdout (lines starting with `{`; everything
//! else is commentary):
//!
//! - `bench: "request"` — per-op latency aggregate (count, vertex_counts,
//!   apply_edges) over the traffic mix: requests, total/mean/max secs.
//! - `bench: "pool"` — the final [`PoolStats`]: hits, misses, hit rate,
//!   evictions by cause, resident bytes. The run drives a byte budget
//!   sized for ~2 of its 3 graphs, so nonzero `evictions_byte_budget`
//!   with a high hit rate is the expected (asserted) shape.
//! - `bench: "amortize"` — pooled vs throwaway: the same query stream
//!   served by the pool vs paying `Session::load` per request, the
//!   multi-graph analogue of the session-reuse ablation.
//! - `bench: "concurrency"` — aggregate scoped-query throughput at
//!   1/2/4/8 client threads over cloned service handles (sessions
//!   pinned to 1 worker so the scaling measured is the service's, not
//!   the scheduler's), plus the derived `concurrent_speedup` row.
//! - `bench: "reader_latency_during_commits"` — mean scoped-read
//!   latency with and without a concurrent writer committing delta
//!   batches to the same graph: snapshot isolation says the two should
//!   track each other.
//! - `bench: "telemetry_overhead"` — min-of-rounds wall time of the
//!   same count stream with telemetry enabled vs disabled; the spans +
//!   registry must cost <= 3% on the count path (asserted), with
//!   bit-identical results.
//! - `bench: "happy_path_overhead"` — min-of-rounds wall time of the
//!   same counts through the cancellable path (a far-future deadline
//!   token polled every work unit) vs the plain path; the per-unit
//!   check must cost <= 2% (asserted), with bit-identical results.
//! - `bench: "cancellation_latency"` — cancel a running k=4 count from
//!   another thread and measure cancel-to-return; must stay within a
//!   few work units' cost (asserted against the measured unit cost).
//!
//! Defaults: 3 G(n, 0.01) directed graphs, n = 2000, 6 traffic rounds.
//! CI shrinks it with `--n 600`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use vdmc::engine::{AbortReason, CancelToken, CountQuery, Scope, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::service::{GraphSource, Request, Response, ServiceConfig, TelemetryConfig, VdmcService};
use vdmc::stream::EdgeDelta;
use vdmc::util::json::Json;

struct Opts {
    n: usize,
    rounds: usize,
    seed: u64,
}

fn parse_opts() -> Opts {
    let mut opts = Opts { n: 2000, rounds: 6, seed: 42 };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("{} needs a value", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--n" => opts.n = take(&mut i).parse().expect("--n"),
            "--rounds" => opts.rounds = take(&mut i).parse().expect("--rounds"),
            "--seed" => opts.seed = take(&mut i).parse().expect("--seed"),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

#[derive(Default)]
struct Lat {
    requests: usize,
    total: f64,
    max: f64,
}

impl Lat {
    fn push(&mut self, secs: f64) {
        self.requests += 1;
        self.total += secs;
        self.max = self.max.max(secs);
    }

    fn row(&self, op: &str) -> Json {
        let mut j = Json::obj();
        j.set("bench", "request")
            .set("op", op)
            .set("requests", self.requests)
            .set("total_secs", self.total)
            .set("mean_secs", if self.requests == 0 { 0.0 } else { self.total / self.requests as f64 })
            .set("max_secs", self.max);
        j
    }
}

fn load_req(id: &str, g: &Graph) -> Request {
    Request::LoadGraph {
        graph: id.to_string(),
        source: GraphSource::Edges { n: g.n(), edges: g.out.edges().collect() },
        directed: true,
    }
}

fn main() {
    let opts = parse_opts();
    println!("# service bench: 3 × G({}, 0.01) directed, {} rounds", opts.n, opts.rounds);

    let graphs: Vec<(String, Graph)> = (0..3u64)
        .map(|s| (format!("g{s}"), generators::gnp_directed(opts.n, 0.01, opts.seed + s)))
        .collect();

    // budget sized for ~2 resident sessions: real traffic sees evictions
    let per = Session::load_with(&graphs[0].1, &SessionConfig::default()).memory_bytes();
    let svc = VdmcService::new(ServiceConfig {
        max_graphs: 0,
        byte_budget: per * 2 + per / 2,
        ..Default::default()
    });

    // the query mix, built through the shared validating builder
    let q3 = CountQuery::builder()
        .size_k(3)
        .direction_name("directed")
        .scheduler_name("stealing")
        .sink_name("sharded")
        .build()
        .expect("valid names");

    let mut load = Lat::default();
    let mut count = Lat::default();
    let mut vertex = Lat::default();
    let mut apply = Lat::default();
    let t_all = Instant::now();
    for (id, g) in &graphs {
        let (r, secs) = svc.handle_timed(load_req(id, g));
        r.expect("load");
        load.push(secs);
    }
    for round in 0..opts.rounds {
        for (id, g) in &graphs {
            // a miss (evicted graph) is reloaded — that is the serving story
            if !svc.with_pool(|p| p.contains(id)) {
                let (r, secs) = svc.handle_timed(load_req(id, g));
                r.expect("reload");
                load.push(secs);
            }
            let (r, secs) =
                svc.handle_timed(Request::Count { graph: id.clone(), query: q3.clone() });
            r.expect("count");
            count.push(secs);

            let probe: Vec<u32> = (0..g.n() as u32).step_by((g.n() / 8).max(1)).collect();
            let (r, secs) = svc.handle_timed(Request::VertexCounts {
                graph: id.clone(),
                size: MotifSize::Three,
                direction: Direction::Directed,
                scope: Scope::Vertices(probe),
            });
            r.expect("vertex_counts");
            vertex.push(secs);

            let n = g.n() as u32;
            let deltas: Vec<EdgeDelta> = (0..10u32)
                .map(|i| {
                    let a = (i * 19 + round as u32 * 7 + 1) % n;
                    let b = (i * 31 + round as u32 * 3 + 2) % n;
                    if a == b {
                        EdgeDelta::insert(a, (b + 1) % n)
                    } else {
                        EdgeDelta::insert(a, b)
                    }
                })
                .collect();
            let (r, secs) = svc.handle_timed(Request::ApplyEdges { graph: id.clone(), deltas });
            r.expect("apply_edges");
            apply.push(secs);
        }
    }
    let pooled_secs = t_all.elapsed().as_secs_f64();

    for (op, lat) in
        [("load_graph", &load), ("count", &count), ("vertex_counts", &vertex), ("apply_edges", &apply)]
    {
        println!("{}", lat.row(op).to_string_compact());
    }

    let stats = match svc.handle(Request::Stats).expect("stats") {
        Response::Stats { pool, .. } => pool,
        other => panic!("{other:?}"),
    };
    let mut j = Json::obj();
    j.set("bench", "pool").set("rounds", opts.rounds).set("pooled_secs", pooled_secs);
    if let Json::Obj(m) = stats.to_json() {
        for (k, v) in m {
            j.set(&k, v);
        }
    }
    println!("{}", j.to_string_compact());
    assert!(stats.hits > 0, "traffic mix must produce pool hits");
    assert!(
        stats.evictions_byte_budget > 0,
        "a 2.5-session budget over 3 graphs must evict at least once"
    );

    // amortization: the same count stream without a pool (throwaway
    // sessions, the seed coordinator's behavior)
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..opts.rounds {
        for (_, g) in &graphs {
            let session = Session::load_with(g, &SessionConfig::default());
            sink = sink.wrapping_add(session.count(&q3).expect("count").total_instances);
        }
    }
    let throwaway_secs = t0.elapsed().as_secs_f64();
    // pooled cost of the same count stream: loads (incl. eviction
    // reloads) + count requests — the deltas/lookups aren't part of the
    // throwaway baseline and are excluded
    let pooled_counts_secs = load.total + count.total;
    let mut j = Json::obj();
    j.set("bench", "amortize")
        .set("pooled_secs", pooled_counts_secs)
        .set("throwaway_secs", throwaway_secs)
        .set("pooled_speedup", throwaway_secs / pooled_counts_secs.max(1e-9))
        .set("checksum", sink);
    println!("{}", j.to_string_compact());

    // -- telemetry overhead: same count stream, spans + registry on/off --
    // interleaved min-of-rounds: the cheapest observed pass of each
    // config, so scheduler noise cancels instead of accumulating
    println!("# telemetry overhead: interleaved count stream, enabled vs disabled");
    let telemetry_svc = |enabled: bool| -> VdmcService {
        let svc = VdmcService::new(ServiceConfig {
            max_graphs: 0,
            byte_budget: 0,
            telemetry: TelemetryConfig { enabled, ..Default::default() },
            ..Default::default()
        });
        for (id, g) in &graphs {
            svc.handle(load_req(id, g)).expect("load");
        }
        svc
    };
    let count_stream = |svc: &VdmcService| -> (f64, u64) {
        let t0 = Instant::now();
        let mut checksum = 0u64;
        for (id, _) in &graphs {
            let (r, _) =
                svc.handle_timed(Request::Count { graph: id.clone(), query: q3.clone() });
            checksum = checksum.wrapping_add(match r.expect("count") {
                Response::Counted { counts, .. } => counts.total_instances,
                other => panic!("{other:?}"),
            });
        }
        (t0.elapsed().as_secs_f64(), checksum)
    };
    let on = telemetry_svc(true);
    let off = telemetry_svc(false);
    count_stream(&on); // warm both pools before timing
    count_stream(&off);
    let telemetry_rounds = 5usize;
    let (mut best_on, mut best_off) = (f64::INFINITY, f64::INFINITY);
    let (mut sum_on, mut sum_off) = (0u64, 0u64);
    for _ in 0..telemetry_rounds {
        let (s_on, c_on) = count_stream(&on);
        let (s_off, c_off) = count_stream(&off);
        best_on = best_on.min(s_on);
        best_off = best_off.min(s_off);
        sum_on = sum_on.wrapping_add(c_on);
        sum_off = sum_off.wrapping_add(c_off);
    }
    assert_eq!(sum_on, sum_off, "telemetry must not change what gets counted");
    let overhead_pct = (best_on / best_off.max(1e-9) - 1.0) * 100.0;
    let mut j = Json::obj();
    j.set("bench", "telemetry_overhead")
        .set("rounds", telemetry_rounds)
        .set("enabled_secs", best_on)
        .set("disabled_secs", best_off)
        .set("overhead_pct", overhead_pct)
        .set("checksum", sum_on);
    println!("{}", j.to_string_compact());
    assert!(
        overhead_pct <= 3.0,
        "full telemetry must cost <= 3% on the count path, got {overhead_pct:.2}%"
    );

    // -- concurrency: scoped-query throughput vs client threads ----------
    // sessions pinned to 1 worker each, so the only parallelism is the
    // client threads sharing pinned snapshots through cloned handles —
    // this measures the service's concurrency, not the scheduler's
    println!("# concurrency: scoped counts over cloned handles, 1-worker sessions");
    let csvc = VdmcService::new(ServiceConfig {
        session: SessionConfig { workers: 1, ..Default::default() },
        max_graphs: 0,
        byte_budget: 0,
        ..Default::default()
    });
    for (id, g) in &graphs {
        csvc.handle(load_req(id, g)).expect("load");
    }
    let per_client = 32usize;
    let base = &q3;
    let mut qps_by_clients: Vec<(usize, f64)> = Vec::new();
    for clients in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for c in 0..clients {
                let svc = csvc.clone();
                let graphs = &graphs;
                s.spawn(move || {
                    for i in 0..per_client {
                        let (id, g) = &graphs[(c + i) % graphs.len()];
                        let seed = ((c * 131 + i * 17) % g.n()) as u32;
                        let q = CountQuery {
                            scope: Scope::Neighborhood { seeds: vec![seed], radius: 1 },
                            ..base.clone()
                        };
                        svc.handle(Request::Count { graph: id.clone(), query: q })
                            .expect("scoped count");
                    }
                });
            }
        });
        let secs = t0.elapsed().as_secs_f64();
        let qps = (clients * per_client) as f64 / secs.max(1e-9);
        qps_by_clients.push((clients, qps));
        let mut j = Json::obj();
        j.set("bench", "concurrency")
            .set("clients", clients)
            .set("requests", clients * per_client)
            .set("secs", secs)
            .set("throughput_qps", qps);
        println!("{}", j.to_string_compact());
    }
    let serial_qps = qps_by_clients[0].1;
    let (max_clients, max_qps) =
        qps_by_clients.iter().copied().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    let speedup = max_qps / serial_qps.max(1e-9);
    let mut j = Json::obj();
    j.set("bench", "concurrent_speedup")
        .set("clients", max_clients)
        .set("serial_qps", serial_qps)
        .set("concurrent_qps", max_qps)
        .set("speedup", speedup);
    println!("{}", j.to_string_compact());
    assert!(
        speedup >= 2.0,
        "8 concurrent clients over shared snapshots must beat serial by >= 2x \
         (target 4x on 8 cores), got {speedup:.2}x"
    );

    // -- reader latency while a writer commits ---------------------------
    // snapshot isolation: a reader pins an epoch and never waits on the
    // writer's commit, so the busy mean should track the idle mean
    let timed_read = |i: usize| -> f64 {
        let (id, g) = &graphs[i % graphs.len()];
        let q = CountQuery {
            scope: Scope::Neighborhood { seeds: vec![(i * 23 % g.n()) as u32], radius: 1 },
            ..base.clone()
        };
        let t = Instant::now();
        csvc.handle(Request::Count { graph: id.clone(), query: q }).expect("scoped count");
        t.elapsed().as_secs_f64()
    };
    let reads = 48usize;
    let mut idle = Lat::default();
    for i in 0..reads {
        idle.push(timed_read(i));
    }
    let stop = AtomicBool::new(false);
    let mut busy = Lat::default();
    std::thread::scope(|s| {
        s.spawn(|| {
            // the writer: keep committing delta batches to every graph
            // until the readers are done
            let mut round = 0u32;
            while !stop.load(Ordering::Relaxed) {
                for (id, g) in &graphs {
                    let n = g.n() as u32;
                    let deltas: Vec<EdgeDelta> = (0..8u32)
                        .map(|i| {
                            let a = (i * 13 + round * 7 + 1) % n;
                            let b = (i * 29 + round * 11 + 2) % n;
                            EdgeDelta::insert(a, if a == b { (b + 1) % n } else { b })
                        })
                        .collect();
                    csvc.handle(Request::ApplyEdges { graph: id.clone(), deltas })
                        .expect("apply_edges");
                }
                round += 1;
            }
        });
        for i in 0..reads {
            busy.push(timed_read(i));
        }
        stop.store(true, Ordering::Relaxed);
    });
    let idle_mean = idle.total / idle.requests.max(1) as f64;
    let busy_mean = busy.total / busy.requests.max(1) as f64;
    let mut j = Json::obj();
    j.set("bench", "reader_latency_during_commits")
        .set("reads", reads)
        .set("idle_mean_secs", idle_mean)
        .set("busy_mean_secs", busy_mean)
        .set("busy_over_idle", busy_mean / idle_mean.max(1e-9));
    println!("{}", j.to_string_compact());

    // -- happy-path overhead of the cancellation machinery ---------------
    // the cancellable path polls the token once per work unit; against a
    // token that never fires (far-future deadline) that poll is the whole
    // cost. Same interleaved min-of-rounds discipline as the telemetry
    // row, on a dedicated session so pool effects can't leak in.
    println!("# happy-path overhead: cancellable vs plain count path");
    let hp_session = Session::load_with(&graphs[0].1, &SessionConfig::default());
    let hp_snap = hp_session.snapshot();
    let far_token = CancelToken::after(Duration::from_secs(3600));
    let plain_pass = || -> (f64, u64) {
        let t0 = Instant::now();
        let mut checksum = 0u64;
        for _ in 0..3 {
            let (counts, _) = hp_snap.count_with_report(&q3).expect("count");
            checksum = checksum.wrapping_add(counts.total_instances);
        }
        (t0.elapsed().as_secs_f64(), checksum)
    };
    let cancellable_pass = || -> (f64, u64) {
        let t0 = Instant::now();
        let mut checksum = 0u64;
        for _ in 0..3 {
            let (counts, _) =
                hp_snap.count_with_report_cancel(&q3, Some(&far_token)).expect("count");
            checksum = checksum.wrapping_add(counts.total_instances);
        }
        (t0.elapsed().as_secs_f64(), checksum)
    };
    plain_pass(); // warm the cached setup before timing
    cancellable_pass();
    let hp_rounds = 5usize;
    let (mut best_plain, mut best_cancel) = (f64::INFINITY, f64::INFINITY);
    let (mut sum_plain, mut sum_cancel) = (0u64, 0u64);
    for _ in 0..hp_rounds {
        let (s_p, c_p) = plain_pass();
        let (s_c, c_c) = cancellable_pass();
        best_plain = best_plain.min(s_p);
        best_cancel = best_cancel.min(s_c);
        sum_plain = sum_plain.wrapping_add(c_p);
        sum_cancel = sum_cancel.wrapping_add(c_c);
    }
    assert_eq!(sum_plain, sum_cancel, "the token must not change what gets counted");
    let hp_overhead_pct = (best_cancel / best_plain.max(1e-9) - 1.0) * 100.0;
    let mut j = Json::obj();
    j.set("bench", "happy_path_overhead")
        .set("rounds", hp_rounds)
        .set("cancellable_secs", best_cancel)
        .set("plain_secs", best_plain)
        .set("overhead_pct", hp_overhead_pct)
        .set("checksum", sum_plain);
    println!("{}", j.to_string_compact());
    assert!(
        hp_overhead_pct <= 2.0,
        "the per-unit cancellation check must cost <= 2% on the count path, \
         got {hp_overhead_pct:.2}%"
    );

    // -- cancellation latency: cancel-to-return, mid-run -----------------
    // workers poll per work unit, so cancel-to-return should cost about
    // one unit (the unit in progress finishes) plus joins. Asserted with
    // 4x unit-cost slack and a 10ms floor for sleep/scheduler jitter.
    println!("# cancellation latency: cross-thread cancel of a k=4 count");
    let q4 = CountQuery::builder()
        .size_k(4)
        .direction_name("directed")
        .scheduler_name("stealing")
        .sink_name("sharded")
        .build()
        .expect("valid names");
    let (_, full_report) = hp_snap.count_with_report(&q4).expect("k4 count");
    let t0 = Instant::now();
    hp_snap.count_with_report(&q4).expect("k4 count");
    let full_secs = t0.elapsed().as_secs_f64();
    let unit_secs = full_secs / full_report.queue_units.max(1) as f64;
    // aim the cancel at ~25% of the run; if a noisy run finishes before
    // the sleep lands, retry with a shorter fuse instead of flaking
    let mut latency_secs = f64::INFINITY;
    let mut aborted = false;
    for attempt in 0..5u32 {
        let cancel_token = CancelToken::new();
        let fuse = (full_secs * 0.25 / (1 << attempt) as f64).max(1e-4);
        let (lat, ab) = std::thread::scope(|s| {
            let runner = s.spawn(|| {
                let r = hp_snap.count_with_report_cancel(&q4, Some(&cancel_token));
                (Instant::now(), r.is_err())
            });
            std::thread::sleep(Duration::from_secs_f64(fuse));
            let t_cancel = Instant::now();
            cancel_token.cancel(AbortReason::ClientGone);
            let (t_end, ab) = runner.join().expect("cancelled runner");
            (t_end.saturating_duration_since(t_cancel).as_secs_f64(), ab)
        });
        if ab {
            latency_secs = lat;
            aborted = true;
            break;
        }
    }
    let bound_secs = (unit_secs * 4.0).max(0.010);
    let mut j = Json::obj();
    j.set("bench", "cancellation_latency")
        .set("latency_secs", latency_secs)
        .set("unit_secs", unit_secs)
        .set("bound_secs", bound_secs)
        .set("full_secs", full_secs)
        .set("units", full_report.queue_units);
    println!("{}", j.to_string_compact());
    assert!(aborted, "the cancel must land mid-run and abort the count");
    assert!(
        latency_secs <= bound_secs,
        "cancel-to-return must stay within a few work units \
         ({latency_secs:.4}s > {bound_secs:.4}s bound)"
    );
}
