//! Service-layer benchmark: pool hit/miss behavior and per-request
//! latency of the `VdmcService` façade under interleaved multi-graph
//! traffic — the serving-path numbers `BENCH_service.json` tracks.
//!
//! One JSON row per line on stdout (lines starting with `{`; everything
//! else is commentary):
//!
//! - `bench: "request"` — per-op latency aggregate (count, vertex_counts,
//!   apply_edges) over the traffic mix: requests, total/mean/max secs.
//! - `bench: "pool"` — the final [`PoolStats`]: hits, misses, hit rate,
//!   evictions by cause, resident bytes. The run drives a byte budget
//!   sized for ~2 of its 3 graphs, so nonzero `evictions_byte_budget`
//!   with a high hit rate is the expected (asserted) shape.
//! - `bench: "amortize"` — pooled vs throwaway: the same query stream
//!   served by the pool vs paying `Session::load` per request, the
//!   multi-graph analogue of the session-reuse ablation.
//!
//! Defaults: 3 G(n, 0.01) directed graphs, n = 2000, 6 traffic rounds.
//! CI shrinks it with `--n 600`.

use std::time::Instant;

use vdmc::engine::{CountQuery, Scope, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::service::{GraphSource, Request, Response, ServiceConfig, VdmcService};
use vdmc::stream::EdgeDelta;
use vdmc::util::json::Json;

struct Opts {
    n: usize,
    rounds: usize,
    seed: u64,
}

fn parse_opts() -> Opts {
    let mut opts = Opts { n: 2000, rounds: 6, seed: 42 };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("{} needs a value", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--n" => opts.n = take(&mut i).parse().expect("--n"),
            "--rounds" => opts.rounds = take(&mut i).parse().expect("--rounds"),
            "--seed" => opts.seed = take(&mut i).parse().expect("--seed"),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

#[derive(Default)]
struct Lat {
    requests: usize,
    total: f64,
    max: f64,
}

impl Lat {
    fn push(&mut self, secs: f64) {
        self.requests += 1;
        self.total += secs;
        self.max = self.max.max(secs);
    }

    fn row(&self, op: &str) -> Json {
        let mut j = Json::obj();
        j.set("bench", "request")
            .set("op", op)
            .set("requests", self.requests)
            .set("total_secs", self.total)
            .set("mean_secs", if self.requests == 0 { 0.0 } else { self.total / self.requests as f64 })
            .set("max_secs", self.max);
        j
    }
}

fn load_req(id: &str, g: &Graph) -> Request {
    Request::LoadGraph {
        graph: id.to_string(),
        source: GraphSource::Edges { n: g.n(), edges: g.out.edges().collect() },
        directed: true,
    }
}

fn main() {
    let opts = parse_opts();
    println!("# service bench: 3 × G({}, 0.01) directed, {} rounds", opts.n, opts.rounds);

    let graphs: Vec<(String, Graph)> = (0..3u64)
        .map(|s| (format!("g{s}"), generators::gnp_directed(opts.n, 0.01, opts.seed + s)))
        .collect();

    // budget sized for ~2 resident sessions: real traffic sees evictions
    let per = Session::load_with(&graphs[0].1, &SessionConfig::default()).memory_bytes();
    let mut svc = VdmcService::new(ServiceConfig {
        max_graphs: 0,
        byte_budget: per * 2 + per / 2,
        ..Default::default()
    });

    // the query mix, built through the shared validating builder
    let q3 = CountQuery::builder()
        .size_k(3)
        .direction_name("directed")
        .scheduler_name("stealing")
        .sink_name("sharded")
        .build()
        .expect("valid names");

    let mut load = Lat::default();
    let mut count = Lat::default();
    let mut vertex = Lat::default();
    let mut apply = Lat::default();
    let t_all = Instant::now();
    for (id, g) in &graphs {
        let (r, secs) = svc.handle_timed(load_req(id, g));
        r.expect("load");
        load.push(secs);
    }
    for round in 0..opts.rounds {
        for (id, g) in &graphs {
            // a miss (evicted graph) is reloaded — that is the serving story
            if !svc.pool().contains(id) {
                let (r, secs) = svc.handle_timed(load_req(id, g));
                r.expect("reload");
                load.push(secs);
            }
            let (r, secs) =
                svc.handle_timed(Request::Count { graph: id.clone(), query: q3.clone() });
            r.expect("count");
            count.push(secs);

            let probe: Vec<u32> = (0..g.n() as u32).step_by((g.n() / 8).max(1)).collect();
            let (r, secs) = svc.handle_timed(Request::VertexCounts {
                graph: id.clone(),
                size: MotifSize::Three,
                direction: Direction::Directed,
                scope: Scope::Vertices(probe),
            });
            r.expect("vertex_counts");
            vertex.push(secs);

            let n = g.n() as u32;
            let deltas: Vec<EdgeDelta> = (0..10u32)
                .map(|i| {
                    let a = (i * 19 + round as u32 * 7 + 1) % n;
                    let b = (i * 31 + round as u32 * 3 + 2) % n;
                    if a == b {
                        EdgeDelta::insert(a, (b + 1) % n)
                    } else {
                        EdgeDelta::insert(a, b)
                    }
                })
                .collect();
            let (r, secs) = svc.handle_timed(Request::ApplyEdges { graph: id.clone(), deltas });
            r.expect("apply_edges");
            apply.push(secs);
        }
    }
    let pooled_secs = t_all.elapsed().as_secs_f64();

    for (op, lat) in
        [("load_graph", &load), ("count", &count), ("vertex_counts", &vertex), ("apply_edges", &apply)]
    {
        println!("{}", lat.row(op).to_string_compact());
    }

    let stats = match svc.handle(Request::Stats).expect("stats") {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    let mut j = Json::obj();
    j.set("bench", "pool").set("rounds", opts.rounds).set("pooled_secs", pooled_secs);
    if let Json::Obj(m) = stats.to_json() {
        for (k, v) in m {
            j.set(&k, v);
        }
    }
    println!("{}", j.to_string_compact());
    assert!(stats.hits > 0, "traffic mix must produce pool hits");
    assert!(
        stats.evictions_byte_budget > 0,
        "a 2.5-session budget over 3 graphs must evict at least once"
    );

    // amortization: the same count stream without a pool (throwaway
    // sessions, the seed coordinator's behavior)
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..opts.rounds {
        for (_, g) in &graphs {
            let session = Session::load_with(g, &SessionConfig::default());
            sink = sink.wrapping_add(session.count(&q3).expect("count").total_instances);
        }
    }
    let throwaway_secs = t0.elapsed().as_secs_f64();
    // pooled cost of the same count stream: loads (incl. eviction
    // reloads) + count requests — the deltas/lookups aren't part of the
    // throwaway baseline and are excluded
    let pooled_counts_secs = load.total + count.total;
    let mut j = Json::obj();
    j.set("bench", "amortize")
        .set("pooled_secs", pooled_counts_secs)
        .set("throwaway_secs", throwaway_secs)
        .set("pooled_speedup", throwaway_secs / pooled_counts_secs.max(1e-9))
        .set("checksum", sink);
    println!("{}", j.to_string_compact());
}
