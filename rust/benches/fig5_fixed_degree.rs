//! Fig. 5 regeneration: run time at FIXED average degree 10 as n grows —
//! the regime where the paper shows (a) cost linear in the number of
//! motifs, (b) the ~10x C++-over-Python gap, and (c) the flat GPU curve
//! until threads saturate.
//!
//! Output TSV: k, n, edges, impl, secs, instances, inst_per_sec.
//! The `python` column stops early (it is the slow curve by construction).

use vdmc::baselines;
use vdmc::coordinator::{count_motifs, CountConfig};
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::timer::time_once;

fn main() {
    let full = std::env::var("VDMC_BENCH_FULL").is_ok();
    println!("# Fig 5 — fixed average degree 10, undirected G(n, 10/(n-1))");
    println!("# k\tn\tedges\timpl\tsecs\tinstances\tinst_per_sec");

    let ns: &[usize] =
        if full { &[250, 500, 1000, 2000, 4000, 8000, 16000] } else { &[250, 500, 1000, 2000, 4000] };

    for &(size, k) in &[(MotifSize::Three, 3usize), (MotifSize::Four, 4usize)] {
        for &n in ns {
            let p = 10.0 / (n as f64 - 1.0);
            let g = generators::gnp_undirected(n, p, 100 + n as u64);
            let dir = Direction::Undirected;

            let (c, secs) = time_once(|| {
                count_motifs(&g, &CountConfig { size, direction: dir, workers: 1, ..Default::default() })
                    .unwrap()
            });
            println!(
                "{k}\t{n}\t{}\tvdmc\t{:.4}\t{}\t{:.3e}",
                g.m(),
                secs.as_secs_f64(),
                c.total_instances,
                c.total_instances as f64 / secs.as_secs_f64().max(1e-9)
            );

            let (mt, mt_secs) = time_once(|| {
                count_motifs(&g, &CountConfig { size, direction: dir, workers: 4, ..Default::default() })
                    .unwrap()
            });
            assert_eq!(mt.total_instances, c.total_instances);
            println!(
                "{k}\t{n}\t{}\tvdmc-mt\t{:.4}\t{}\t{:.3e}",
                g.m(),
                mt_secs.as_secs_f64(),
                mt.total_instances,
                mt.total_instances as f64 / mt_secs.as_secs_f64().max(1e-9)
            );

            // python-parity curve: cap the workload (it is ~10x slower)
            if n <= if full { 4000 } else { 2000 } {
                let (slow, slow_secs) = time_once(|| baselines::slow::count(&g, size, dir));
                assert_eq!(slow.total_instances, c.total_instances);
                println!(
                    "{k}\t{n}\t{}\tpython\t{:.4}\t{}\t{:.3e}",
                    g.m(),
                    slow_secs.as_secs_f64(),
                    slow.total_instances,
                    slow.total_instances as f64 / slow_secs.as_secs_f64().max(1e-9)
                );
            }
        }
    }
    println!("# expectations: per-k inst_per_sec roughly constant for vdmc (cost linear in motifs);");
    println!("# python ~10x slower (paper Fig 5); crossover vs GPU happens only above thread capacity.");
}
