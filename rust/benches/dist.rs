//! Distribution-layer benchmark: what the scatter-gather router costs
//! over a single-process session on the same machine — the merge + wire
//! overhead `BENCH_dist.json` tracks. Workers are real `serve_tcp`
//! processes-in-threads on loopback TCP, so every number includes the
//! JSONL codec and socket round-trips the production cluster pays.
//!
//! One JSON row per line on stdout (lines starting with `{`; everything
//! else is commentary):
//!
//! - `bench: "dist_count"` — full k=3 count at 1/2/4 shards: router
//!   mean secs over rounds, the single-process baseline, and the
//!   `router_over_single` ratio. 1 shard isolates pure wire + merge
//!   cost; more shards add the gather fan-in. Results are asserted
//!   bit-identical to the baseline at every width.
//! - `bench: "dist_rows"` — scoped vertex_counts lookups (16 rows)
//!   through the router vs the in-process service; the scatter hits
//!   only owner shards, so this is the interactive-lookup overhead.
//! - `bench: "dist_apply"` — an edge-delta batch through the router
//!   (ghost-fringe fetch + fan-out + authoritative merge) vs
//!   `Session::apply_edges`, then a post-batch count identity check.
//!
//! Defaults: G(1500, 0.01) directed, 5 rounds. CI shrinks it with
//! `--n 500`.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use vdmc::dist::{worker, Router, ShardPlan};
use vdmc::engine::{CountQuery, Scope, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::service::{GraphSource, Request, Response, ServeOptions, VdmcService};
use vdmc::stream::EdgeDelta;
use vdmc::util::json::Json;

struct Opts {
    n: usize,
    rounds: usize,
    seed: u64,
}

fn parse_opts() -> Opts {
    let mut opts = Opts { n: 1500, rounds: 5, seed: 42 };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("{} needs a value", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--n" => opts.n = take(&mut i).parse().expect("--n"),
            "--rounds" => opts.rounds = take(&mut i).parse().expect("--rounds"),
            "--seed" => opts.seed = take(&mut i).parse().expect("--seed"),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

/// An in-process cluster: worker threads on loopback listeners plus a
/// connected router; dropped workers drain and join.
struct Cluster {
    router: Router,
    flags: Vec<Arc<AtomicBool>>,
    handles: Vec<Option<JoinHandle<()>>>,
}

fn start_cluster(g: &Graph, k_max: usize, shards: usize) -> Cluster {
    let listeners: Vec<TcpListener> =
        (0..shards).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind")).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().expect("addr").to_string()).collect();
    let plan = ShardPlan::build(g, "g", "<mem>", k_max, &addrs, 64).expect("plan");
    let mut flags = Vec::new();
    let mut handles = Vec::new();
    for (s, listener) in listeners.into_iter().enumerate() {
        let local = worker::induced_local(&plan, s, g).expect("induced slice");
        let svc =
            worker::worker_service(&plan, s, local, SessionConfig::default()).expect("worker");
        let flag = Arc::new(AtomicBool::new(false));
        flags.push(Arc::clone(&flag));
        handles.push(Some(std::thread::spawn(move || {
            serve(svc, listener, flag);
        })));
    }
    let router = Router::connect(plan).expect("connect");
    Cluster { router, flags, handles }
}

fn serve(svc: VdmcService, listener: TcpListener, flag: Arc<AtomicBool>) {
    vdmc::service::serve_tcp(&svc, listener, &ServeOptions::default(), &flag).expect("serve");
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for f in &self.flags {
            f.store(true, Ordering::SeqCst);
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

fn main() {
    let opts = parse_opts();
    println!("# dist bench: G({}, 0.01) directed, {} rounds", opts.n, opts.rounds);
    let g = generators::gnp_directed(opts.n, 0.01, opts.seed);
    let session = Session::load(&g);
    let q3 = CountQuery {
        size: MotifSize::Three,
        direction: Direction::Directed,
        ..Default::default()
    };

    // single-process baseline: min-of-rounds so scheduler noise cancels
    let mut single_secs = f64::INFINITY;
    let mut baseline = session.count(&q3).expect("baseline count");
    for _ in 0..opts.rounds {
        let t0 = Instant::now();
        baseline = session.count(&q3).expect("baseline count");
        single_secs = single_secs.min(t0.elapsed().as_secs_f64());
    }

    // -- dist_count: full count at 1/2/4 shards ---------------------------
    for shards in [1usize, 2, 4] {
        let cluster = start_cluster(&g, 3, shards);
        let count = || match cluster
            .router
            .handle(Request::Count { graph: "g".into(), query: q3.clone() }, None)
            .expect("router count")
        {
            Response::Counted { counts, .. } => counts,
            other => panic!("{other:?}"),
        };
        let warm = count(); // dial + maintain once before timing
        assert_eq!(warm.per_vertex, baseline.per_vertex, "{shards}-shard counts drifted");
        assert_eq!(warm.total_instances, baseline.total_instances);
        let mut router_secs = f64::INFINITY;
        for _ in 0..opts.rounds {
            let t0 = Instant::now();
            let got = count();
            router_secs = router_secs.min(t0.elapsed().as_secs_f64());
            assert_eq!(got.total_instances, baseline.total_instances);
        }
        let mut j = Json::obj();
        j.set("bench", "dist_count")
            .set("shards", shards)
            .set("rounds", opts.rounds)
            .set("router_secs", router_secs)
            .set("single_secs", single_secs)
            .set("router_over_single", router_secs / single_secs.max(1e-9))
            .set("total_instances", baseline.total_instances);
        println!("{}", j.to_string_compact());
    }

    // -- dist_rows: interactive scoped lookups at 2 shards ----------------
    let cluster = start_cluster(&g, 3, 2);
    let svc = VdmcService::with_defaults();
    svc.handle(Request::LoadGraph {
        graph: "g".into(),
        source: GraphSource::Edges { n: g.n(), edges: g.out.edges().collect() },
        directed: true,
    })
    .expect("load");
    let probe: Vec<u32> = (0..g.n() as u32).step_by((g.n() / 16).max(1)).take(16).collect();
    let rows_req = || Request::VertexCounts {
        graph: "g".into(),
        size: MotifSize::Three,
        direction: Direction::Directed,
        scope: Scope::Vertices(probe.clone()),
    };
    let local_rows = match svc.handle(rows_req()).expect("local rows") {
        Response::VertexRows { rows, .. } => rows,
        other => panic!("{other:?}"),
    };
    let routed_rows = match cluster.router.handle(rows_req(), None).expect("routed rows") {
        Response::VertexRows { rows, .. } => rows,
        other => panic!("{other:?}"),
    };
    assert_eq!(routed_rows.len(), local_rows.len());
    for (a, b) in routed_rows.iter().zip(&local_rows) {
        assert_eq!((a.vertex, &a.counts), (b.vertex, &b.counts), "routed row drifted");
    }
    let lookups = 64usize;
    let timed = |go: &dyn Fn()| -> f64 {
        let t0 = Instant::now();
        for _ in 0..lookups {
            go();
        }
        t0.elapsed().as_secs_f64() / lookups as f64
    };
    let local_mean = timed(&|| {
        svc.handle(rows_req()).expect("local rows");
    });
    let routed_mean = timed(&|| {
        cluster.router.handle(rows_req(), None).expect("routed rows");
    });
    let mut j = Json::obj();
    j.set("bench", "dist_rows")
        .set("shards", 2)
        .set("lookups", lookups)
        .set("row_count", probe.len())
        .set("router_mean_secs", routed_mean)
        .set("local_mean_secs", local_mean)
        .set("router_over_local", routed_mean / local_mean.max(1e-9));
    println!("{}", j.to_string_compact());

    // -- dist_apply: a delta batch through the ghost-fringe fan-out -------
    let n = g.n() as u32;
    let mut oracle = Session::load(&g);
    let mut router_secs = 0.0f64;
    let mut oracle_secs = 0.0f64;
    let apply_rounds = opts.rounds.max(2);
    for round in 0..apply_rounds as u32 {
        let deltas: Vec<EdgeDelta> = (0..16u32)
            .map(|i| {
                let a = (i * 19 + round * 7 + 1) % n;
                let b = (i * 31 + round * 3 + 2) % n;
                EdgeDelta::insert(a, if a == b { (b + 1) % n } else { b })
            })
            .collect();
        let t0 = Instant::now();
        let want = oracle.apply_edges(&deltas).expect("oracle apply");
        oracle_secs += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let got = match cluster
            .router
            .handle(Request::ApplyEdges { graph: "g".into(), deltas }, None)
            .expect("routed apply")
        {
            Response::Applied { report, .. } => report,
            other => panic!("{other:?}"),
        };
        router_secs += t0.elapsed().as_secs_f64();
        assert_eq!(
            (got.inserted, got.deleted, got.skipped_duplicate),
            (want.inserted, want.deleted, want.skipped_duplicate),
            "round {round} delta accounting drifted"
        );
    }
    let post = oracle.count(&q3).expect("post count");
    let routed_post = match cluster
        .router
        .handle(Request::Count { graph: "g".into(), query: q3.clone() }, None)
        .expect("post routed count")
    {
        Response::Counted { counts, .. } => counts,
        other => panic!("{other:?}"),
    };
    assert_eq!(routed_post.per_vertex, post.per_vertex, "post-apply counts drifted");
    let mut j = Json::obj();
    j.set("bench", "dist_apply")
        .set("shards", 2)
        .set("batches", apply_rounds)
        .set("deltas_per_batch", 16)
        .set("router_secs", router_secs)
        .set("single_secs", oracle_secs)
        .set("router_over_single", router_secs / oracle_secs.max(1e-9))
        .set("post_total_instances", post.total_instances);
    println!("{}", j.to_string_compact());
}
