//! Fig. 4 regeneration: run time as a function of vertex AND edge count,
//! for undirected (left panel) and directed (right panel) 4-motifs, across
//! implementations:
//!
//!   - `vdmc`       the optimized coordinator (this paper's C++/CUDA analog)
//!   - `python`     the hash/alloc-heavy "python-parity" baseline
//!                  (paper Section 8: "C++ ... approximately 10 times more
//!                  efficient than its parallel in Python")
//!   - `vdmc-mt`    the coordinator with a full worker pool — the GPU-like
//!                  configuration whose curve should flatten vs n while
//!                  the pool is unsaturated (single-core hosts will show
//!                  queue overhead only; see EXPERIMENTS.md)
//!
//! Output: TSV rows  panel, n, edges, impl, secs, instances, inst_per_sec.
//! VDMC_BENCH_FULL=1 extends the sweep to larger n.

use vdmc::baselines;
use vdmc::coordinator::{count_motifs, CountConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::timer::time_once;

fn bench_graph(panel: &str, g: &Graph, dir: Direction, slow_ok: bool) {
    let size = MotifSize::Four;
    let (counts, secs) = time_once(|| {
        count_motifs(g, &CountConfig { size, direction: dir, workers: 1, ..Default::default() }).unwrap()
    });
    let row = |imp: &str, s: f64, inst: u64| {
        println!(
            "{panel}\t{}\t{}\t{imp}\t{:.4}\t{inst}\t{:.3e}",
            g.n(),
            g.m(),
            s,
            inst as f64 / s.max(1e-9)
        );
    };
    row("vdmc", secs.as_secs_f64(), counts.total_instances);

    let (mt, mt_secs) = time_once(|| {
        count_motifs(g, &CountConfig { size, direction: dir, workers: 4, ..Default::default() }).unwrap()
    });
    assert_eq!(mt.per_vertex, counts.per_vertex, "multithreaded counts must match");
    row("vdmc-mt", mt_secs.as_secs_f64(), mt.total_instances);

    if slow_ok {
        let (slow, slow_secs) = time_once(|| baselines::slow::count(g, size, dir));
        assert_eq!(slow.total_instances, counts.total_instances, "python-parity counts must match");
        row("python", slow_secs.as_secs_f64(), slow.total_instances);
    }
}

fn main() {
    let full = std::env::var("VDMC_BENCH_FULL").is_ok();
    println!("# Fig 4 — runtime vs (n, E), 4-motifs; implementations: vdmc / vdmc-mt / python");
    println!("# panel\tn\tedges\timpl\tsecs\tinstances\tinst_per_sec");

    let ns: &[usize] = if full { &[200, 400, 800, 1600, 3200] } else { &[200, 400, 800] };
    let degrees: &[f64] = &[5.0, 10.0, 20.0];

    for &n in ns {
        for &d in degrees {
            // undirected panel: G_U(n, p) with mean degree d
            let p = d / (n as f64 - 1.0);
            let gu = generators::gnp_undirected(n, p, 7 + n as u64);
            bench_graph("undirected", &gu, Direction::Undirected, n <= 800);

            // directed panel: directed G(n, p') with the same undirected density
            let pd = p / 2.0;
            let gd = generators::gnp_directed(n, pd, 7 + n as u64);
            bench_graph("directed", &gd, Direction::Directed, n <= 800);
        }
    }
    println!("# shape expectations: secs grows ~linearly with instance count;");
    println!("# python/vdmc ratio ~10x (paper Section 8); vdmc-mt tracks vdmc on 1-core hosts.");
}
