//! Hot-path probe microbenchmark + adjacency-tier ablation.
//!
//! Two measurements, one JSON row per line on stdout (lines starting with
//! `{`; everything else is commentary):
//!
//! 1. `bench: "probe"` — raw membership-probe latency against the hub
//!    rows of a hub-heavy (power-law) graph: the CSR binary search vs the
//!    hybrid tier's single word test, same pair stream, checksum-guarded
//!    so neither loop can be optimized away.
//! 2. `bench: "gallop"` — the row-merge strategies raced on the same
//!    hub-row × sparse-target workload: the two-pointer
//!    `bits_against_merge` walk vs the galloping dispatch `bits_against`
//!    takes when `|targets| * GALLOP_RATIO <= |row|`, checksum-guarded
//!    bit-identical, with a `gallop_speedup` row.
//! 3. `bench: "count"` — end-to-end counting wall-clock of `--adjacency
//!    csr` vs `--adjacency hybrid` sessions on the same graph, plus a
//!    `speedup` row per k. Both k = 3 and k = 4 run by default
//!    (`--k3-only` to skip the slower k = 4): the 3-BFS assembles ids
//!    from mark bits alone (no pair probes — its rows are the no-effect
//!    control), while the 4-BFS is the probe-bound path the tier
//!    accelerates, so the **k = 4 speedup row is the acceptance
//!    measurement** for the tiered-adjacency PR: on ≥50k-edge hub-heavy
//!    graphs the hybrid rows are expected to win there.
//!
//! Defaults build a Barabási–Albert graph with n = 20_000, m = 3
//! (≈ 60k undirected edges). CI's bench-smoke job shrinks it with
//! `cargo bench --bench hotpath -- --n 4000` and archives the rows as
//! `BENCH_hotpath.json` so the perf trajectory is tracked per commit.

use std::time::Instant;

use vdmc::engine::{AdjacencyMode, CountQuery, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::{generators, GraphProbe};
use vdmc::motifs::probe::{bits_against, bits_against_merge, GALLOP_RATIO};
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::json::Json;
use vdmc::util::rng::Pcg32;

struct Opts {
    n: usize,
    ba_m: usize,
    seed: u64,
    workers: usize,
    k3_only: bool,
}

fn parse_opts() -> Opts {
    let mut opts = Opts { n: 20_000, ba_m: 3, seed: 42, workers: 4, k3_only: false };
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let take = |i: &mut usize| -> String {
            *i += 1;
            args.get(*i).unwrap_or_else(|| panic!("{} needs a value", args[*i - 1])).clone()
        };
        match args[i].as_str() {
            "--n" => opts.n = take(&mut i).parse().expect("--n"),
            "--ba" => opts.ba_m = take(&mut i).parse().expect("--ba"),
            "--seed" => opts.seed = take(&mut i).parse().expect("--seed"),
            "--workers" => opts.workers = take(&mut i).parse().expect("--workers"),
            "--k3-only" => opts.k3_only = true,
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg {other:?}"),
        }
        i += 1;
    }
    opts
}

/// Probe-pair stream biased the way the enumerator's probes are: one
/// endpoint drawn from the heaviest rows, the other uniform.
fn probe_pairs(g: &Graph, count: usize, seed: u64) -> Vec<(u32, u32)> {
    let n = g.n() as u32;
    let mut heavy: Vec<u32> = (0..n).collect();
    heavy.sort_by_key(|&v| std::cmp::Reverse(g.und_degree(v)));
    heavy.truncate((n as usize / 50).max(1));
    let mut rng = Pcg32::seeded(seed);
    (0..count)
        .map(|_| (heavy[rng.below(heavy.len() as u32) as usize], rng.below(n)))
        .collect()
}

fn probe_row(mode: &str, probes: usize, secs: f64, hits: u64) -> Json {
    let mut j = Json::obj();
    j.set("bench", "probe")
        .set("mode", mode)
        .set("probes", probes)
        .set("secs", secs)
        .set("ns_per_probe", secs * 1e9 / probes as f64)
        .set("hits", hits);
    j
}

fn main() {
    let opts = parse_opts();
    let g = generators::barabasi_albert(opts.n, opts.ba_m, opts.seed);
    println!(
        "# hotpath on BA({}, {}) seed {}: n={} m={} (undirected)",
        opts.n,
        opts.ba_m,
        opts.seed,
        g.n(),
        g.m()
    );

    // ---- 1. probe microbenchmark: binary search vs bitmap word test
    let mut hybrid_graph = g.clone();
    let threshold = hybrid_graph.enable_hybrid(None);
    let pairs = probe_pairs(&g, 2_000_000, opts.seed ^ 0x5EED);
    println!(
        "# hybrid tier: threshold {} -> {} hub rows, {} KiB",
        threshold,
        hybrid_graph.hub_rows(),
        hybrid_graph.tier_memory_bytes() / 1024
    );

    let t0 = Instant::now();
    let mut hits_csr = 0u64;
    for &(u, v) in &pairs {
        hits_csr += g.und.has_edge(u, v) as u64;
    }
    let csr_secs = t0.elapsed().as_secs_f64();
    println!("{}", probe_row("binary-search", pairs.len(), csr_secs, hits_csr).to_string_compact());

    let t0 = Instant::now();
    let mut hits_hub = 0u64;
    for &(u, v) in &pairs {
        hits_hub += hybrid_graph.has_und_fast(u, v) as u64;
    }
    let hub_secs = t0.elapsed().as_secs_f64();
    println!("{}", probe_row("bitmap", pairs.len(), hub_secs, hits_hub).to_string_compact());
    assert_eq!(hits_csr, hits_hub, "probe parity violated");

    // ---- 2. row-merge microbenchmark: two-pointer merge vs galloping
    // the 4-BFS shape the gallop path exists for: a hub's long sorted row
    // intersected with a short candidate list
    let hub = (0..g.n() as u32).max_by_key(|&v| g.und_degree(v)).unwrap();
    let row_len = g.und.neighbors_above(hub, 0).len();
    let t_count = (row_len / GALLOP_RATIO).max(1);
    let step = (g.n() / t_count).max(1);
    let targets: Vec<u32> =
        (1..g.n() as u32).step_by(step).filter(|&t| t != hub).take(t_count).collect();
    assert!(
        targets.len() * GALLOP_RATIO <= row_len,
        "target list too dense to exercise the gallop dispatch"
    );
    println!(
        "# gallop workload: hub v{hub} row {row_len} entries x {} targets, {} reps",
        targets.len(),
        50_000
    );
    let reps = 50_000usize;
    let t0 = Instant::now();
    let mut sum_merge = 0u64;
    for _ in 0..reps {
        bits_against_merge(&g, Direction::Undirected, hub, 0, &targets, |t, b| {
            sum_merge = sum_merge.wrapping_add(t as u64 + b as u64);
        });
    }
    let merge_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut sum_gallop = 0u64;
    for _ in 0..reps {
        bits_against(&g, Direction::Undirected, hub, 0, &targets, |t, b| {
            sum_gallop = sum_gallop.wrapping_add(t as u64 + b as u64);
        });
    }
    let gallop_secs = t0.elapsed().as_secs_f64();
    assert_eq!(sum_merge, sum_gallop, "gallop parity violated");
    for (mode, secs) in [("merge", merge_secs), ("gallop", gallop_secs)] {
        let mut j = Json::obj();
        j.set("bench", "gallop")
            .set("mode", mode)
            .set("row_len", row_len)
            .set("targets", targets.len())
            .set("reps", reps)
            .set("secs", secs)
            .set("ns_per_call", secs * 1e9 / reps as f64);
        println!("{}", j.to_string_compact());
    }
    let mut j = Json::obj();
    j.set("bench", "gallop_speedup")
        .set("row_len", row_len)
        .set("targets", targets.len())
        .set("gallop_speedup", merge_secs / gallop_secs.max(1e-12));
    println!("{}", j.to_string_compact());

    // ---- 3. counting wall-clock: csr vs hybrid sessions
    let sizes: &[MotifSize] =
        if opts.k3_only { &[MotifSize::Three] } else { &[MotifSize::Three, MotifSize::Four] };
    for &size in sizes {
        let mut secs_by_mode = [0.0f64; 2];
        let mut expected = None;
        for (mi, mode) in [AdjacencyMode::Csr, AdjacencyMode::Hybrid].into_iter().enumerate() {
            let session = Session::load_with(
                &g,
                &SessionConfig { workers: opts.workers, adjacency: mode, ..Default::default() },
            );
            // warm-up query, then the measured one (cached setup for both)
            let q = CountQuery { size, direction: Direction::Undirected, ..Default::default() };
            let _ = session.count(&q).unwrap();
            let (c, r) = session.count_with_report(&q).unwrap();
            let want = *expected.get_or_insert(c.total_instances);
            assert_eq!(c.total_instances, want, "tier changed the counts");
            secs_by_mode[mi] = r.elapsed_secs;
            let mut j = Json::obj();
            j.set("bench", "count")
                .set("adjacency", mode.label())
                .set("k", size.k())
                .set("workers", session.workers())
                .set("n", g.n())
                .set("m", g.m())
                .set("secs", r.elapsed_secs)
                .set("instances", c.total_instances)
                .set("throughput_per_sec", r.throughput())
                .set("tier_memory_bytes", r.tier_memory_bytes)
                .set("hub_rows", session.hub_rows());
            println!("{}", j.to_string_compact());
        }
        let mut j = Json::obj();
        j.set("bench", "speedup")
            .set("k", size.k())
            .set("csr_secs", secs_by_mode[0])
            .set("hybrid_secs", secs_by_mode[1])
            .set("hybrid_speedup", secs_by_mode[0] / secs_by_mode[1].max(1e-12));
        println!("{}", j.to_string_compact());
    }
    println!("# expectation: k=4 hybrid_speedup > 1 on hub-heavy graphs (bitmap rows beat binary");
    println!("# search on the probe-bound 4-BFS); k=3 rows are the no-effect control (~1.0).");
}
