//! Fig. 3 regeneration: expected (Eq. 7.4) vs observed motif frequencies on
//! G(n, p), directed and undirected, 3- and 4-motifs, with the paper's
//! chi-square acceptance criterion (calibrated by parametric bootstrap —
//! see theory::calibrated_fig3_fit docs for why plain Pearson over-rejects
//! on correlated motif counts).
//!
//! Prints one table per panel: class id, observed instances, expected,
//! log10 values (the quantity Fig. 3 plots), and the fit verdict.
//!
//! Scale note: panels default to CPU-friendly sizes; VDMC_BENCH_FULL=1
//! switches to the paper's G(1000, 0.1) for all panels.

use vdmc::coordinator::{count_motifs, CountConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::theory;

fn count_instances(g: &Graph, size: MotifSize, dir: Direction) -> Vec<f64> {
    count_motifs(g, &CountConfig { size, direction: dir, workers: 1, ..Default::default() })
        .unwrap()
        .class_instances()
        .iter()
        .map(|&x| x as f64)
        .collect()
}

fn main() {
    let full = std::env::var("VDMC_BENCH_FULL").is_ok();
    println!("# Fig 3 — theory vs VDMC (full-scale: {full})");

    let k4 = if full { (1000usize, 0.1f64, 6usize) } else { (250, 0.03, 8) };
    let panels: Vec<(MotifSize, Direction, usize, f64, usize)> = vec![
        (MotifSize::Three, Direction::Undirected, 1000, 0.1, 10),
        (MotifSize::Three, Direction::Directed, 1000, 0.1, 10),
        (MotifSize::Four, Direction::Undirected, k4.0, k4.1, k4.2),
        (MotifSize::Four, Direction::Directed, k4.0, k4.1, k4.2),
    ];

    let mut accepted = 0;
    let mut total_panels = 0;
    for (size, dir, n, p, replicates) in panels {
        let k = size.k();
        let dname = if dir == Direction::Directed { "directed" } else { "undirected" };
        println!("\n## panel: {dname} {k}-motifs, G({n}, {p})");

        let g = match dir {
            Direction::Directed => generators::gnp_directed(n, p, 2024),
            Direction::Undirected => generators::gnp_undirected(n, p, 2024),
        };
        let observed = count_instances(&g, size, dir);
        let expected = theory::expected_instances(k, dir, n, p);

        println!("{:>8} {:>14} {:>14} {:>9} {:>9}", "class", "observed", "expected", "log10(o)", "log10(e)");
        for (s, (o, e)) in observed.iter().zip(&expected).enumerate() {
            if *e >= 0.5 || *o > 0.0 {
                println!(
                    "{s:>8} {o:>14.0} {e:>14.1} {:>9.3} {:>9.3}",
                    (o + 1.0).log10(),
                    (e + 1.0).log10()
                );
            }
        }

        let fit = theory::calibrated_fig3_fit(k, dir, n, p, &observed, replicates, 99, |g| {
            count_instances(g, size, dir)
        });
        total_panels += 1;
        if fit.chi.accepts_at_5pct() {
            accepted += 1;
        }
        println!(
            "chi2 = {:.2} (df {}, dropped {}) p = {:.3} -> {}",
            fit.chi.statistic,
            fit.chi.df,
            fit.chi.dropped,
            fit.chi.p_value,
            if fit.chi.accepts_at_5pct() { "ACCEPT (matches paper)" } else { "REJECT" }
        );
    }
    println!("\n# verdict: {accepted}/{total_panels} panels non-significant at 5% (paper: all panels)");
}
