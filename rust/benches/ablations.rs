//! Ablations over the design choices DESIGN.md calls out:
//!
//!   A. counter strategy: shared atomics (the paper's GPU atomicAdd) vs
//!      per-worker shards merged at the end;
//!   B. degree-descending reorder (paper Section 6) on vs off;
//!   C. work-item granularity (max (root, neighbor) units per queue item);
//!   D. worker-count scaling on a heavy-hub graph.
//!
//! Output TSV: ablation, config, secs, instances, imbalance.

use vdmc::coordinator::{count_motifs_with_report, CountConfig};
use vdmc::graph::generators;
use vdmc::motifs::counter::CounterMode;
use vdmc::motifs::{Direction, MotifSize};

fn main() {
    println!("# ablations on BA(4000, 6) undirected 4-motifs (heavy hubs)");
    println!("# ablation\tconfig\tsecs\tinstances\timbalance");
    let g = generators::barabasi_albert(4000, 6, 55);
    let base = CountConfig {
        size: MotifSize::Four,
        direction: Direction::Undirected,
        workers: 2,
        ..Default::default()
    };

    // A: counter strategy
    for (label, mode) in [("atomic", CounterMode::Atomic), ("sharded", CounterMode::Sharded)] {
        let cfg = CountConfig { counter: mode, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("counter\t{label}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // B: reorder
    for (label, reorder) in [("degree-desc", true), ("identity", false)] {
        let cfg = CountConfig { reorder, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("reorder\t{label}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // C: work-item granularity
    for units in [1usize, 8, 64, 512, 100_000] {
        let cfg = CountConfig { max_units_per_item: units, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("granularity\t{units}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // D: worker scaling
    for workers in [1usize, 2, 4, 8] {
        let cfg = CountConfig { workers, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("workers\t{workers}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    println!("# all configs must report identical instance totals (asserted in tests);");
    println!("# on multi-core hosts vdmc expects: sharded <= atomic, degree-desc <= identity,");
    println!("# granularity sweet spot mid-range, near-linear worker scaling until core count.");
}
