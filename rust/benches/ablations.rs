//! Ablations over the design choices DESIGN.md calls out:
//!
//!   A. counter strategy: shared atomics (the paper's GPU atomicAdd) vs
//!      per-worker shards merged at the end vs partition-local writes;
//!   B. degree-descending reorder (paper Section 6) on vs off;
//!   C. work-item granularity (max (root, neighbor) units per queue item);
//!   D. worker-count scaling on a heavy-hub graph;
//!   E. scheduler × sink grid (shared cursor vs single-item work stealing
//!      vs half-deque batch stealing, all three sinks) — one JSON row per
//!      combination, including steal_batch totals/averages, so the engine
//!      refactor's wins are measured, not asserted;
//!   F. session reuse: first query (pays setup) vs Nth query (cached);
//!   G. adjacency tier: pure-CSR binary-search probes vs the hybrid
//!      bitmap hub rows, one JSON row per (tier, k) with tier memory —
//!      `benches/hotpath.rs` is the companion microbenchmark.
//!
//! Sections A–D print the historical TSV (ablation, config, secs,
//! instances, imbalance); sections E–F emit one compact JSON object per
//! line, machine-readable for dashboards.

use vdmc::coordinator::{count_motifs_with_report, CountConfig};
use vdmc::engine::{AdjacencyMode, CountQuery, SchedulerMode, Session, SessionConfig};
use vdmc::graph::generators;
use vdmc::motifs::counter::CounterMode;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::json::Json;

const SCHEDULERS: [(&str, SchedulerMode); 3] = [
    ("cursor", SchedulerMode::SharedCursor),
    ("stealing", SchedulerMode::WorkStealing),
    ("stealing-batch", SchedulerMode::WorkStealingBatch),
];
const SINKS: [(&str, CounterMode); 3] = [
    ("atomic", CounterMode::Atomic),
    ("sharded", CounterMode::Sharded),
    ("partition", CounterMode::PartitionLocal),
];

fn main() {
    println!("# ablations on BA(4000, 6) undirected 4-motifs (heavy hubs)");
    println!("# ablation\tconfig\tsecs\tinstances\timbalance");
    let g = generators::barabasi_albert(4000, 6, 55);
    let base = CountConfig {
        size: MotifSize::Four,
        direction: Direction::Undirected,
        workers: 2,
        ..Default::default()
    };

    // A: counter strategy
    for (label, mode) in SINKS {
        let cfg = CountConfig { counter: mode, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("counter\t{label}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // B: reorder
    for (label, reorder) in [("degree-desc", true), ("identity", false)] {
        let cfg = CountConfig { reorder, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("reorder\t{label}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // C: work-item granularity
    for units in [1usize, 8, 64, 512, 100_000] {
        let cfg = CountConfig { max_units_per_item: units, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("granularity\t{units}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // D: worker scaling
    for workers in [1usize, 2, 4, 8] {
        let cfg = CountConfig { workers, ..base.clone() };
        let (c, r) = count_motifs_with_report(&g, &cfg).unwrap();
        println!("workers\t{workers}\t{:.4}\t{}\t{:.3}", c.elapsed_secs, c.total_instances, r.imbalance());
    }

    // E: scheduler × sink grid, served from one cached session so every
    // combination counts the same partitioned work. One JSON row each.
    println!("# scheduler x sink grid (JSON rows)");
    let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
    let mut expected_instances = None;
    for (sched_label, scheduler) in SCHEDULERS {
        for (sink_label, sink) in SINKS {
            let query = CountQuery {
                size: MotifSize::Four,
                direction: Direction::Undirected,
                scheduler,
                sink,
                ..Default::default()
            };
            let (c, r) = session.count_with_report(&query).unwrap();
            let expected = *expected_instances.get_or_insert(c.total_instances);
            assert_eq!(c.total_instances, expected, "{sched_label}/{sink_label} diverged");
            // the report's class histogram must sum to the instance total
            // and agree with the count matrix on every grid row
            assert_eq!(
                r.per_class_totals.iter().sum::<u64>(),
                c.total_instances,
                "{sched_label}/{sink_label} per_class_totals"
            );
            assert_eq!(r.per_class_totals, c.class_instances(), "{sched_label}/{sink_label}");
            let mut j = Json::obj();
            j.set("ablation", "scheduler_x_sink")
                .set("scheduler", sched_label)
                .set("sink", sink_label)
                .set("workers", session.workers())
                .set("secs", r.elapsed_secs)
                .set("instances", c.total_instances)
                .set("throughput_per_sec", r.throughput())
                .set("imbalance", r.imbalance())
                .set("steals", r.total_steals())
                .set("steal_batch_total", r.total_steal_batch())
                .set("steal_batch_avg", r.avg_steal_batch());
            println!("{}", j.to_string_compact());
        }
    }

    // F: session reuse — setup amortization across repeated queries.
    println!("# session reuse (JSON rows)");
    let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
    let query = CountQuery {
        size: MotifSize::Three,
        direction: Direction::Undirected,
        ..Default::default()
    };
    for call in 0..3u64 {
        let (_, r) = session.count_with_report(&query).unwrap();
        let mut j = Json::obj();
        j.set("ablation", "session_reuse")
            .set("call", call)
            .set("secs", r.elapsed_secs)
            .set("setup_secs", r.setup_secs)
            .set("setup_reused", r.setup_reused);
        println!("{}", j.to_string_compact());
    }

    // G: adjacency tier — csr vs hybrid, both motif sizes, cached sessions
    // so only the probe tier differs between the rows.
    println!("# adjacency tier (JSON rows)");
    for (label, adjacency) in
        [("csr", AdjacencyMode::Csr), ("hybrid", AdjacencyMode::Hybrid)]
    {
        let session =
            Session::load_with(&g, &SessionConfig { workers: 4, adjacency, ..Default::default() });
        for size in [MotifSize::Three, MotifSize::Four] {
            let query =
                CountQuery { size, direction: Direction::Undirected, ..Default::default() };
            let _ = session.count(&query).unwrap(); // warm-up
            let (c, r) = session.count_with_report(&query).unwrap();
            let mut j = Json::obj();
            j.set("ablation", "adjacency")
                .set("adjacency", label)
                .set("k", size.k())
                .set("workers", session.workers())
                .set("secs", r.elapsed_secs)
                .set("instances", c.total_instances)
                .set("throughput_per_sec", r.throughput())
                .set("tier_memory_bytes", r.tier_memory_bytes)
                .set("hub_rows", session.hub_rows());
            println!("{}", j.to_string_compact());
        }
    }

    println!("# all configs must report identical instance totals (asserted above and in tests);");
    println!("# on multi-core hosts vdmc expects: sharded/partition <= atomic, degree-desc <= identity,");
    println!("# granularity sweet spot mid-range, near-linear worker scaling until core count,");
    println!("# stealing <= cursor on hub-heavy graphs, call>=1 session rows with setup_secs=0,");
    println!("# and adjacency hybrid <= csr (bitmap hub rows beat binary searches on hubs).");
}
