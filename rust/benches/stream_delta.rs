//! Stream-delta acceptance bench: on a ~50k-edge G(n,p) digraph, apply a
//! 100-edge delta batch through `Session::apply_edges` and check that
//!
//!   (a) the maintained 3- and 4-motif counts equal a full
//!       reload-and-recount of the mutated graph, and
//!   (b) the delta path re-enumerated < 5% of the full unit count
//!       (units = proper (root, neighbor) pairs = |E_und|).
//!
//! Emits one JSON row for the batch and one for the full-recount
//! comparison, plus a timeline-style sweep over batch sizes.

use vdmc::engine::{CountQuery, Session, SessionConfig};
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::stream::EdgeDelta;
use vdmc::util::json::Json;
use vdmc::util::rng::Pcg32;

fn random_batch(n: u32, len: usize, seed: u64) -> Vec<EdgeDelta> {
    let mut rng = Pcg32::seeded(seed);
    (0..len)
        .map(|_| {
            let u = rng.below(n);
            let v = rng.below(n);
            if rng.bernoulli(0.5) {
                EdgeDelta::insert(u, v)
            } else {
                EdgeDelta::delete(u, v)
            }
        })
        .collect()
}

fn main() {
    let (n, p) = (10_000usize, 5.0e-4);
    let g = generators::gnp_directed(n, p, 4242);
    println!("# stream delta on directed G({n}, {p}): m={} (~50k edges)", g.m());

    let mut session = Session::load_with(&g, &SessionConfig { workers: 0, ..Default::default() });
    session.maintain(MotifSize::Three, Direction::Directed).unwrap();
    session.maintain(MotifSize::Four, Direction::Directed).unwrap();
    let full_units = session.partitions().total_units;

    let batch = random_batch(n as u32, 100, 77);
    let t0 = std::time::Instant::now();
    let report = session.apply_edges(&batch).unwrap();
    let apply_secs = t0.elapsed().as_secs_f64();
    let frac = report.reenumerated_units as f64 / full_units.max(1) as f64;

    let mut j = report.to_json();
    j.set("bench", "apply_100_edge_batch")
        .set("full_units", full_units)
        .set("reenumerated_fraction", frac)
        .set("apply_secs", apply_secs);
    println!("{}", j.to_string_compact());
    assert!(
        frac < 0.05,
        "delta batch re-enumerated {:.2}% of the graph (acceptance bound: 5%)",
        frac * 100.0
    );

    // full reload-and-recount oracle
    let snapshot = session.snapshot_graph();
    let t1 = std::time::Instant::now();
    let fresh = Session::load(&snapshot);
    for size in [MotifSize::Three, MotifSize::Four] {
        let want = fresh
            .count(&CountQuery { size, direction: Direction::Directed, ..Default::default() })
            .unwrap();
        let got = session.maintained_counts(size, Direction::Directed).unwrap();
        assert_eq!(got.per_vertex, want.per_vertex, "k={} per-vertex mismatch", size.k());
        assert_eq!(got.total_instances, want.total_instances);
    }
    let recount_secs = t1.elapsed().as_secs_f64();
    let mut j = Json::obj();
    j.set("bench", "reload_recount_oracle")
        .set("recount_secs", recount_secs)
        .set("apply_secs", apply_secs)
        .set("speedup", recount_secs / apply_secs.max(1e-9));
    println!("{}", j.to_string_compact());

    // batch-size sweep: incremental cost should scale with the batch, not
    // with the graph
    for (i, batch_len) in [10usize, 100, 1000].into_iter().enumerate() {
        let deltas = random_batch(n as u32, batch_len, 1000 + i as u64);
        let t = std::time::Instant::now();
        let r = session.apply_edges(&deltas).unwrap();
        let mut j = r.to_json();
        j.set("bench", "batch_sweep")
            .set("batch_len", batch_len)
            .set("apply_secs", t.elapsed().as_secs_f64())
            .set("reenumerated_fraction", r.reenumerated_units as f64 / full_units.max(1) as f64);
        println!("{}", j.to_string_compact());
    }
    println!("# maintained counts verified against a full reload-and-recount; fraction < 5% asserted");
}
