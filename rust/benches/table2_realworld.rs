//! Table 1 + Table 2 regeneration on real-world *analogs*.
//!
//! The paper's datasets (web-BerkStan, as-Skitter, soc-LiveJournal,
//! com-Orkut) are SNAP downloads; this environment has no network, so each
//! dataset is replaced by a Barabási–Albert scale-free graph matched to its
//! |V| and |E| at 1/100 scale (DESIGN.md documents the substitution — BA
//! graphs exercise the same heavy-hub code path that motivates the paper's
//! (root, neighbor) work splitting). 4-motif runs use a further 1/10
//! vertex scale-down by default (the paper's own 4-motif column is
//! hours-of-V100); VDMC_BENCH_FULL=1 lifts that.
//!
//! Output TSV: dataset, k, n, edges, secs, instances, inst_per_sec,
//! paper_secs (the V100 number from Table 2 for shape comparison).
//!
//! To run against the real SNAP files instead, download them and point
//! VDMC_DATASET_DIR at edge lists named wbd.tsv, wb.tsv, as.tsv, ljd.tsv,
//! lj.tsv, ok.tsv.

use std::path::Path;

use vdmc::coordinator::{count_motifs_with_report, CountConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::{generators, io};
use vdmc::motifs::{Direction, MotifSize};

struct Dataset {
    name: &'static str,
    file: &'static str,
    directed: bool,
    /// paper-scale vertex count and BA attachment parameter (m ≈ E/V)
    v_full: usize,
    m_attach: usize,
    /// reciprocal-edge probability for directed analogs
    recip: f64,
    /// paper Table 2 elapsed seconds (3-motif, 4-motif); None = not reported
    paper3: Option<f64>,
    paper4: Option<f64>,
    /// default vertex scale-down for the 4-motif run (the densest analogs
    /// need more than the blanket 1/1000 to stay CPU-friendly)
    scale4: usize,
}

const DATASETS: &[Dataset] = &[
    Dataset { name: "WBD", file: "wbd.tsv", directed: true, v_full: 690_000, m_attach: 11, recip: 0.25, paper3: Some(68.0), paper4: Some(23736.0), scale4: 1000 },
    Dataset { name: "WB", file: "wb.tsv", directed: false, v_full: 690_000, m_attach: 10, recip: 0.0, paper3: Some(76.0), paper4: Some(30315.0), scale4: 1000 },
    Dataset { name: "AS", file: "as.tsv", directed: false, v_full: 1_700_000, m_attach: 6, recip: 0.0, paper3: Some(154.0), paper4: Some(6968.0), scale4: 1000 },
    Dataset { name: "LJD", file: "ljd.tsv", directed: true, v_full: 4_800_000, m_attach: 14, recip: 0.3, paper3: Some(635.0), paper4: Some(10882.0), scale4: 2000 },
    Dataset { name: "LJ", file: "lj.tsv", directed: false, v_full: 4_800_000, m_attach: 9, recip: 0.0, paper3: Some(574.0), paper4: Some(4645.0), scale4: 2000 },
    Dataset { name: "OK", file: "ok.tsv", directed: false, v_full: 3_100_000, m_attach: 39, recip: 0.0, paper3: Some(1628.0), paper4: Some(28730.0), scale4: 5000 },
];

fn load_or_generate(d: &Dataset, scale: usize, seed: u64) -> (Graph, &'static str) {
    if let Ok(dir) = std::env::var("VDMC_DATASET_DIR") {
        let path = Path::new(&dir).join(d.file);
        if path.exists() {
            return (io::load_edge_list(&path, d.directed).expect("load dataset"), "snap");
        }
    }
    let n = (d.v_full / scale).max(d.m_attach + 2);
    let g = if d.directed {
        generators::barabasi_albert_directed(n, d.m_attach, d.recip, seed)
    } else {
        generators::barabasi_albert(n, d.m_attach, seed)
    };
    (g, "ba-analog")
}

fn main() {
    let full = std::env::var("VDMC_BENCH_FULL").is_ok();
    println!("# Table 1/2 — real-world analogs (1/100 scale BA; 4-motifs 1/1000 unless FULL)");
    println!("# dataset\tsource\tk\tn\tedges\tsecs\tinstances\tinst_per_sec\tpaper_V100_secs");

    for d in DATASETS {
        for (size, k, paper) in
            [(MotifSize::Three, 3usize, d.paper3), (MotifSize::Four, 4usize, d.paper4)]
        {
            let scale = if k == 4 && !full { d.scale4 } else { 100 };
            let (g, source) = load_or_generate(d, scale, 33);
            let direction = if d.directed { Direction::Directed } else { Direction::Undirected };
            let cfg = CountConfig { size, direction, ..Default::default() };
            let (counts, report) = count_motifs_with_report(&g, &cfg).expect("count");
            println!(
                "{}\t{source}\t{k}\t{}\t{}\t{:.3}\t{}\t{:.3e}\t{}",
                d.name,
                g.n(),
                g.m(),
                counts.elapsed_secs,
                counts.total_instances,
                report.throughput(),
                paper.map(|s| s.to_string()).unwrap_or_else(|| "-".into())
            );
        }
    }
    println!("# shape expectations (paper Table 2): 4-motif time >> 3-motif time on every dataset;");
    println!("# OK (densest) is the heaviest 3-motif dataset; web graphs have the worst 4-motif blowup");
    println!("# (high clustering); directed runs cost more than undirected at equal |E|.");
}
