//! `cargo xtask lint` — the vdmc invariant lint.
//!
//! A std-only, line-oriented scanner over `rust/src` enforcing the
//! concurrency-discipline rules that `rustc`/clippy cannot express:
//!
//! | rule                  | invariant                                             |
//! |-----------------------|-------------------------------------------------------|
//! | `relaxed-justify`     | every `Ordering::Relaxed` carries a `// relaxed:`     |
//! |                       | justification on the same or a nearby preceding line  |
//! | `safety-comment`      | every `unsafe` carries a `// SAFETY:` comment         |
//! | `request-path-unwrap` | no `.unwrap()` / `.expect(` on the serving path       |
//! |                       | (`service/`, `engine/session.rs`) — errors propagate  |
//! | `shim-bypass`         | modules ported to the `crate::sync` loom shim never   |
//! |                       | name `std::sync` / `std::thread` directly             |
//!
//! Scanning is syntactic on purpose: line comments and the contents of
//! string/char literals are stripped before token matching, and
//! everything from a file's first `#[cfg(test)]` to EOF is exempt
//! (tests may unwrap and may drive `std::thread` directly). Block
//! comments and raw strings are not modelled — the tree doesn't use
//! them outside tests, and a false positive is a loud, cheap fix.
//!
//! `cargo xtask lint --self-test` first seeds one violation of each
//! rule class into a temp tree and asserts the scanner reports exactly
//! those, proving the lint still bites before the clean run is trusted.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How many raw lines above a flagged token a justification comment may
/// sit (same line counts too). Large enough for a wrapped 3-line
/// comment plus an attribute; small enough that the justification stays
/// next to the code it covers.
const WINDOW: usize = 8;

/// Modules ported onto the `crate::sync` shim: under `--cfg loom` these
/// compile against loom's instrumented primitives, so a direct
/// `std::sync` / `std::thread` reference would silently escape the
/// model checker. Paths are relative to `rust/src`.
const PORTED: [&str; 5] = [
    "engine/cancel.rs",
    "engine/deque.rs",
    "engine/snapshot.rs",
    "service/admission.rs",
    "telemetry/metrics.rs",
];

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask lint [--self-test]");
            ExitCode::from(2)
        }
    }
}

fn lint(flags: &[String]) -> ExitCode {
    let self_test = match flags {
        [] => false,
        [f] if f == "--self-test" => true,
        other => {
            eprintln!("unknown flags {other:?}; usage: cargo xtask lint [--self-test]");
            return ExitCode::from(2);
        }
    };
    if self_test {
        return match run_self_test() {
            Ok(()) => {
                println!("vdmc-lint: self-test ok (every rule class still detected)");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("vdmc-lint: self-test FAILED: {e}");
                ExitCode::FAILURE
            }
        };
    }
    // xtask lives at rust/xtask; the lint's domain is the library tree.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let violations = match scan_tree(&src) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("vdmc-lint: cannot scan {}: {e}", src.display());
            return ExitCode::from(2);
        }
    };
    if violations.is_empty() {
        println!("vdmc-lint: clean");
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        eprintln!("{v}");
    }
    eprintln!("vdmc-lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}

// ------------------------------------------------------------- scanning

/// Lint every `.rs` file under `src`, deterministically ordered.
fn scan_tree(src: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(src, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(src)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = fs::read_to_string(&path)?;
        out.extend(scan_source(&rel, &text));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file. `rel` is the path relative to `rust/src` with `/`
/// separators — rule scoping matches on it.
fn scan_source(rel: &str, text: &str) -> Vec<Violation> {
    let raw: Vec<&str> = text.lines().collect();
    let code: Vec<String> = raw.iter().map(|l| strip_code(l)).collect();
    // Everything from the first `#[cfg(test)]` onward is test code by
    // repo convention (tests module closes the file).
    let test_start = raw.iter().position(|l| l.contains("cfg(test)")).unwrap_or(raw.len());
    let on_request_path = rel.starts_with("service/") || rel == "engine/session.rs";
    let ported = PORTED.contains(&rel);
    // The shim itself is the one legitimate `std::sync` importer.
    let is_shim = rel == "sync.rs";

    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        out.push(Violation { file: format!("rust/src/{rel}"), line: line + 1, rule, message });
    };
    for (i, line) in code.iter().enumerate().take(test_start) {
        if line.contains("Ordering::Relaxed") && !nearby(&raw, i, "// relaxed:") {
            push(
                i,
                "relaxed-justify",
                format!("Ordering::Relaxed without a `// relaxed:` justification within {WINDOW} lines"),
            );
        }
        if has_word(line, "unsafe") && !nearby(&raw, i, "SAFETY:") {
            push(
                i,
                "safety-comment",
                format!("`unsafe` without a `// SAFETY:` comment within {WINDOW} lines"),
            );
        }
        if on_request_path && (line.contains(".unwrap()") || line.contains(".expect(")) {
            push(
                i,
                "request-path-unwrap",
                "`.unwrap()`/`.expect(` on the request path — propagate an error instead".into(),
            );
        }
        if ported && !is_shim && (line.contains("std::sync") || line.contains("std::thread")) {
            push(
                i,
                "shim-bypass",
                "direct `std::sync`/`std::thread` in a loom-ported module — use `crate::sync`"
                    .into(),
            );
        }
    }
    out
}

/// Does `needle` appear on line `i` or any of the `WINDOW` raw lines
/// above it? (Raw lines: justifications live in comments.)
fn nearby(raw: &[&str], i: usize, needle: &str) -> bool {
    let lo = i.saturating_sub(WINDOW);
    raw[lo..=i].iter().any(|l| l.contains(needle))
}

/// Whole-word containment (so `unsafe` never matches inside a larger
/// identifier).
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(at) = line[from..].find(word) {
        let start = from + at;
        let end = start + word.len();
        let pre = start == 0 || !is_ident(bytes[start - 1]);
        let post = end == bytes.len() || !is_ident(bytes[end]);
        if pre && post {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Strip a line down to the tokens the rules match on: cut `//`
/// comments (including doc comments) and blank out the *contents* of
/// string and char literals, leaving their delimiters. Lifetimes
/// (`'a`) are not char literals and pass through untouched.
fn strip_code(line: &str) -> String {
    let chars: Vec<char> = line.chars().collect();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            break;
        }
        if c == '"' {
            out.push('"');
            i += 1;
            while i < chars.len() {
                if chars[i] == '\\' {
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                }
                i += 1;
            }
            continue;
        }
        if c == '\'' {
            // `'\n'` or `'x'` open a char literal; `'a` is a lifetime
            let is_char =
                chars.get(i + 1) == Some(&'\\') || (chars.get(i + 2) == Some(&'\'')).then_some(())
                    == Some(());
            if is_char {
                out.push('\'');
                i += 1;
                while i < chars.len() && chars[i] != '\'' {
                    if chars[i] == '\\' {
                        i += 1;
                    }
                    i += 1;
                }
                if i < chars.len() {
                    out.push('\'');
                    i += 1;
                }
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

// ------------------------------------------------------------ self-test

/// Seed one violation per rule class (plus clean counterparts) into a
/// temp tree and assert the scanner reports exactly the seeded four —
/// proof the lint still detects each class before a clean run means
/// anything.
fn run_self_test() -> Result<(), String> {
    let root = std::env::temp_dir().join(format!("vdmc-lint-selftest-{}", std::process::id()));
    let src = root.join("src");
    let seeded: &[(&str, &str, &str)] = &[
        (
            "relaxed-justify",
            "engine/seeded_relaxed.rs",
            "pub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n",
        ),
        (
            "safety-comment",
            "motifs/seeded_unsafe.rs",
            "pub fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n",
        ),
        (
            "request-path-unwrap",
            "service/seeded_unwrap.rs",
            "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        ),
        (
            "shim-bypass",
            "engine/deque.rs",
            "use std::sync::Mutex;\npub fn f() {}\n",
        ),
    ];
    let clean: &[(&str, &str)] = &[
        (
            "engine/clean.rs",
            "pub fn f(a: &AtomicUsize, p: *const u32) -> u32 {\n    \
             // relaxed: monitoring read only.\n    let _ = a.load(Ordering::Relaxed);\n    \
             // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n",
        ),
        (
            "service/clean.rs",
            "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    \
             pub fn g(x: Option<u32>) -> u32 {\n        x.unwrap()\n    }\n}\n",
        ),
    ];
    let write_all = || -> std::io::Result<()> {
        for (_, rel, body) in seeded {
            let path = src.join(rel);
            if let Some(dir) = path.parent() {
                fs::create_dir_all(dir)?;
            }
            fs::write(path, body)?;
        }
        for (rel, body) in clean {
            let path = src.join(rel);
            if let Some(dir) = path.parent() {
                fs::create_dir_all(dir)?;
            }
            fs::write(path, body)?;
        }
        Ok(())
    };
    let result = write_all()
        .map_err(|e| format!("cannot seed temp tree: {e}"))
        .and_then(|()| check_seeded(&src, seeded));
    let _ = fs::remove_dir_all(&root);
    result
}

fn check_seeded(src: &Path, seeded: &[(&str, &str, &str)]) -> Result<(), String> {
    let found = scan_tree(src).map_err(|e| format!("scan failed: {e}"))?;
    for v in &found {
        println!("seeded violation detected: {v}");
    }
    let mut got: Vec<(String, String)> =
        found.into_iter().map(|v| (v.rule.to_string(), v.file)).collect();
    got.sort();
    let mut want: Vec<(String, String)> = seeded
        .iter()
        .map(|(rule, rel, _)| (rule.to_string(), format!("rust/src/{rel}")))
        .collect();
    want.sort();
    if got != want {
        return Err(format!("expected exactly the seeded violations {want:?}, got {got:?}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_strings_and_chars_but_not_lifetimes() {
        assert_eq!(strip_code("let x = 1; // Ordering::Relaxed"), "let x = 1; ");
        assert_eq!(strip_code(r#"let s = "unsafe .unwrap()";"#), r#"let s = "";"#);
        assert_eq!(strip_code(r"let c = '\''; let l: &'static str;"), "let c = ''; let l: &'static str;");
        assert_eq!(strip_code(r#"let q = "esc \" quote"; f()"#), r#"let q = ""; f()"#);
    }

    #[test]
    fn word_matching_respects_identifier_boundaries() {
        assert!(has_word("unsafe { }", "unsafe"));
        assert!(has_word("pub unsafe fn g()", "unsafe"));
        assert!(!has_word("let unsafety = 1;", "unsafe"));
        assert!(!has_word("made_unsafe()", "unsafe"));
    }

    #[test]
    fn relaxed_needs_nearby_justification() {
        let bad = "fn f(a: &AtomicU64) -> u64 {\n    a.load(Ordering::Relaxed)\n}\n";
        let v = scan_source("engine/x.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "relaxed-justify");
        assert_eq!(v[0].line, 2);

        let good = "fn f(a: &AtomicU64) -> u64 {\n    // relaxed: tally only.\n    \
                    a.load(Ordering::Relaxed)\n}\n";
        assert!(scan_source("engine/x.rs", good).is_empty());
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let v = scan_source("motifs/x.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "safety-comment");

        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: p is valid by contract.\n    \
                    unsafe { *p }\n}\n";
        assert!(scan_source("motifs/x.rs", good).is_empty());
    }

    #[test]
    fn unwrap_rule_applies_only_on_the_request_path() {
        let body = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
        assert_eq!(scan_source("service/x.rs", body)[0].rule, "request-path-unwrap");
        assert_eq!(scan_source("engine/session.rs", body)[0].rule, "request-path-unwrap");
        assert!(scan_source("engine/x.rs", body).is_empty());
        let expect = "fn f(x: Option<u8>) -> u8 {\n    x.expect(\"set\")\n}\n";
        assert_eq!(scan_source("service/x.rs", expect)[0].rule, "request-path-unwrap");
    }

    #[test]
    fn test_region_is_exempt_from_every_rule() {
        let body = "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g(x: Option<u8>) -> u8 {\n        \
                    let _ = ORD.load(Ordering::Relaxed);\n        x.unwrap()\n    }\n}\n";
        assert!(scan_source("service/x.rs", body).is_empty());
    }

    #[test]
    fn shim_bypass_fires_only_in_ported_modules() {
        let body = "use std::sync::Mutex;\npub fn f() {}\n";
        let v = scan_source("engine/deque.rs", body);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "shim-bypass");
        assert!(scan_source("engine/partition.rs", body).is_empty());
        let thread = "pub fn f() { std::thread::yield_now(); }\n";
        assert_eq!(scan_source("telemetry/metrics.rs", thread)[0].rule, "shim-bypass");
    }

    #[test]
    fn tokens_inside_strings_and_comments_do_not_fire() {
        let body = "pub fn f() -> &'static str {\n    \
                    // mentions unsafe and .unwrap() and Ordering::Relaxed in prose\n    \
                    \"unsafe .unwrap() Ordering::Relaxed std::sync\"\n}\n";
        assert!(scan_source("service/x.rs", body).is_empty());
        assert!(scan_source("engine/deque.rs", body).is_empty());
    }
}
