//! Distribution-layer acceptance: a sharded cluster must be
//! bit-identical to one single-process session over the same graph —
//! counts, per-vertex rows, top-k rankings — including after edge-delta
//! batches (the ghost-fringe invariant under churn), and a dead worker
//! must surface as a typed per-shard error without poisoning queries
//! that only touch healthy shards.
//!
//! Workers here are real `serve_tcp` loops on in-process listeners; the
//! router speaks the same JSONL wire over real sockets that `vdmc
//! worker` serves in production.

use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use vdmc::dist::{worker, Router, ShardError, ShardPlan};
use vdmc::engine::{InstanceList, MotifQuery, Output, QueryOutput, Scope, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifCounts, MotifSize};
use vdmc::service::{
    serve_tcp, GraphSource, Request, Response, ServeOptions, ServiceConfig, VdmcService,
};
use vdmc::stream::EdgeDelta;

/// One live cluster: `shards` worker threads serving their induced
/// slices over real TCP, and a connected router. Dropping it drains and
/// joins every worker.
struct Cluster {
    router: Router,
    graph: String,
    flags: Vec<Arc<AtomicBool>>,
    handles: Vec<Option<JoinHandle<()>>>,
}

impl Cluster {
    /// `None` when the graph cannot sustain `shards` shards (tiny or
    /// hub-dominated graphs clamp the plan) — callers skip that
    /// configuration.
    fn start(g: &Graph, graph_id: &str, k_max: usize, shards: usize) -> Option<Cluster> {
        // bind first so the plan records real ports
        let listeners: Vec<TcpListener> =
            (0..shards).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<String> =
            listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
        let plan = match ShardPlan::build(g, graph_id, "<mem>", k_max, &addrs, 16) {
            Ok(plan) => plan,
            Err(_) => return None,
        };
        let mut flags = Vec::new();
        let mut handles = Vec::new();
        for (s, listener) in listeners.into_iter().enumerate() {
            let local = worker::induced_local(&plan, s, g).unwrap();
            let svc = worker::worker_service(&plan, s, local, SessionConfig::default()).unwrap();
            let flag = Arc::new(AtomicBool::new(false));
            flags.push(Arc::clone(&flag));
            handles.push(Some(std::thread::spawn(move || {
                serve_tcp(&svc, listener, &ServeOptions::default(), &flag).unwrap();
            })));
        }
        let router = Router::connect(plan).unwrap();
        Some(Cluster { router, graph: graph_id.to_string(), flags, handles })
    }

    fn must_start(g: &Graph, graph_id: &str, k_max: usize, shards: usize) -> Cluster {
        Cluster::start(g, graph_id, k_max, shards).expect("plan clamped below requested shards")
    }

    /// Shut one worker down and join it — its listener closes and its
    /// in-flight connections drain, exactly like a process exit.
    fn kill(&mut self, shard: usize) {
        self.flags[shard].store(true, Ordering::SeqCst);
        if let Some(h) = self.handles[shard].take() {
            h.join().unwrap();
        }
    }

    fn count(&self, query: &MotifQuery) -> MotifCounts {
        match self
            .router
            .handle(Request::Count { graph: self.graph.clone(), query: query.clone() }, None)
            .unwrap()
        {
            Response::Counted { counts, .. } => counts,
            other => panic!("{other:?}"),
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for f in &self.flags {
            f.store(true, Ordering::SeqCst);
        }
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

/// The test matrix: (graph id, graph, direction to classify under).
fn graphs() -> Vec<(&'static str, Graph, Direction)> {
    vec![
        ("gnp-dir", generators::gnp_directed(60, 0.08, 7), Direction::Directed),
        ("gnp-und", generators::gnp_undirected(60, 0.10, 3), Direction::Undirected),
        ("star", generators::star(41), Direction::Undirected),
        ("ba", generators::barabasi_albert(50, 3, 5), Direction::Undirected),
    ]
}

fn query(k: usize, direction: Direction) -> MotifQuery {
    let size = MotifSize::from_k(k).unwrap();
    MotifQuery { size, direction, ..Default::default() }
}

/// Inline-edges [`GraphSource`] mirroring a loaded graph.
fn edges_source(g: &Graph) -> GraphSource {
    let edges: Vec<(u32, u32)> = if g.directed {
        g.out.edges().collect()
    } else {
        g.und.edges().filter(|&(u, v)| u < v).collect()
    };
    GraphSource::Edges { n: g.n(), edges }
}

/// Canonical (vertex tuple, class id) view of an instance list — the
/// shape both sides must agree on exactly.
fn canon(l: &InstanceList) -> Vec<(Vec<u32>, u16)> {
    let mut v: Vec<(Vec<u32>, u16)> = l
        .instances
        .iter()
        .map(|i| (i.verts.clone(), l.class_ids[i.class_slot as usize]))
        .collect();
    v.sort();
    v
}

/// Deterministic cross-shard delta batch: inserts span the vertex range
/// (so ghost fan-out fires), deletes hit real and missing edges, plus a
/// duplicate insert and an out-of-range pair for the skip counters.
fn delta_batch(n: u32, round: u32) -> Vec<EdgeDelta> {
    let mut deltas = Vec::new();
    for i in 0..6u32 {
        let a = (i * 7 + round * 13 + 1) % n;
        let b = (n - 1 + i * 11 + round * 5) % n;
        if a != b {
            deltas.push(EdgeDelta::insert(a, b));
            deltas.push(EdgeDelta::delete((a + 3) % n, (b + 1) % n));
        }
    }
    if let Some(first) = deltas.first().copied() {
        deltas.push(first); // duplicate insert → skipped_duplicate
    }
    deltas.push(EdgeDelta::insert(n + 5, 0)); // out of range → skipped_invalid
    deltas
}

#[test]
fn sharded_counts_and_topk_match_the_single_process_oracle() {
    for (name, g, direction) in &graphs() {
        let oracle = Session::load(g);
        for k in [3usize, 4] {
            for shards in [2usize, 4] {
                let cluster = match Cluster::start(g, name, k, shards) {
                    Some(c) => c,
                    None => {
                        eprintln!("{name}: skipping {shards}-shard plan (graph clamps)");
                        continue;
                    }
                };
                let q = query(k, *direction);
                let want = oracle.count(&q).unwrap();
                let got = cluster.count(&q);
                assert_eq!(got.class_ids, want.class_ids, "{name} k={k} s={shards}");
                assert_eq!(got.per_vertex, want.per_vertex, "{name} k={k} s={shards}");
                assert_eq!(
                    got.per_class_instances, want.per_class_instances,
                    "{name} k={k} s={shards}"
                );
                assert_eq!(got.total_instances, want.total_instances, "{name} k={k} s={shards}");

                // top-k rankings share the exact rows, so the identical
                // (count desc, vertex asc) order falls out bit-identically
                let got_top = cluster.router.top_vertices(q.size, q.direction, 5, None).unwrap();
                let want_top = match oracle
                    .query(&MotifQuery { output: Output::TopVertices { k: 5 }, ..q.clone() })
                    .unwrap()
                {
                    QueryOutput::TopVertices(t) => t,
                    other => panic!("{}", other.label()),
                };
                assert_eq!(got_top.per_class, want_top.per_class, "{name} k={k} s={shards}");
                assert_eq!(got_top.class_ids, want_top.class_ids);
                assert_eq!(got_top.total_instances, want_top.total_instances);
            }
        }
    }
}

#[test]
fn scoped_vertex_rows_match_and_keep_client_order() {
    let name = "gnp-dir";
    let g = generators::gnp_directed(60, 0.08, 7);
    let direction = Direction::Directed;
    let oracle = VdmcService::with_defaults();
    oracle
        .handle(Request::LoadGraph {
            graph: name.into(),
            source: edges_source(&g),
            directed: g.directed,
        })
        .unwrap();
    let cluster = Cluster::must_start(&g, name, 4, 2);
    let size = MotifSize::Three;

    // explicit vertex list: duplicates and shard-crossing order must
    // both survive the scatter (rows come back in client order)
    let scopes = vec![
        Scope::Vertices(vec![59, 0, 30, 0, 17]),
        Scope::Neighborhood { seeds: vec![5, 40], radius: 1 },
        Scope::Neighborhood { seeds: vec![12], radius: 3 }, // fringe radius = k_max − 1
    ];
    for scope in scopes {
        let req = |s: Scope| Request::VertexCounts {
            graph: name.into(),
            size,
            direction,
            scope: s,
        };
        let (want_rows, want_ids) = match oracle.handle(req(scope.clone())).unwrap() {
            Response::VertexRows { rows, class_ids, .. } => (rows, class_ids),
            other => panic!("{other:?}"),
        };
        match cluster.router.handle(req(scope.clone()), None).unwrap() {
            Response::VertexRows { rows, class_ids, total_instances, .. } => {
                assert_eq!(class_ids, want_ids, "{scope:?}");
                assert_eq!(rows.len(), want_rows.len(), "{scope:?}");
                for (got, want) in rows.iter().zip(&want_rows) {
                    assert_eq!(got.vertex, want.vertex, "{scope:?}");
                    assert_eq!(got.counts, want.counts, "{scope:?} v{}", got.vertex);
                }
                // the router does not maintain a global instance total on
                // the lookup path (that would force a full gather and
                // defeat partial-health serving): 0 is the documented
                // sentinel — use `count` for totals
                assert_eq!(total_instances, 0, "{scope:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    // a neighborhood past the replicated fringe is a typed refusal, not
    // a silently partial answer
    let err = cluster
        .router
        .handle(
            Request::VertexCounts {
                graph: name.into(),
                size,
                direction,
                scope: Scope::Neighborhood { seeds: vec![0], radius: 9 },
            },
            None,
        )
        .unwrap_err();
    assert!(err.to_string().contains("fringe"), "{err:#}");
}

#[test]
fn instance_lists_merge_loss_free_and_samples_are_deterministic() {
    let g = generators::gnp_undirected(50, 0.10, 11);
    let oracle = Session::load(&g);
    let cluster = Cluster::must_start(&g, "g", 3, 2);
    let direction = Direction::Undirected;
    let size = MotifSize::Three;

    // instances, generous limit: the merged owner-filtered union must be
    // exactly the oracle's list (both sorted by vertex tuple)
    let q = MotifQuery {
        size,
        direction,
        output: Output::Instances { limit: 200_000 },
        ..Default::default()
    };
    let want = match oracle.query(&q).unwrap() {
        QueryOutput::Instances(l) => l,
        other => panic!("{}", other.label()),
    };
    let got = match cluster
        .router
        .handle(Request::Instances { graph: "g".into(), query: q.clone() }, None)
        .unwrap()
    {
        Response::Instances { list, .. } => list,
        other => panic!("{other:?}"),
    };
    assert!(!got.truncated && !want.truncated);
    assert_eq!(got.total_seen, want.total_seen);
    assert_eq!(canon(&got), canon(&want));
    // per-class tallies line up once both slot orders map to class ids
    for (slot, &cid) in got.class_ids.iter().enumerate() {
        let oracle_slot = want.class_ids.iter().position(|&c| c == cid).unwrap();
        assert_eq!(got.per_class_seen[slot], want.per_class_seen[oracle_slot], "m{cid}");
    }

    // a vertex-scoped instance list merges just as loss-free
    let scoped = MotifQuery { scope: Scope::Vertices(vec![0, 25, 49]), ..q.clone() };
    let want_scoped = match oracle.query(&scoped).unwrap() {
        QueryOutput::Instances(l) => l,
        other => panic!("{}", other.label()),
    };
    let got_scoped = match cluster
        .router
        .handle(Request::Instances { graph: "g".into(), query: scoped }, None)
        .unwrap()
    {
        Response::Instances { list, .. } => list,
        other => panic!("{other:?}"),
    };
    assert_eq!(canon(&got_scoped), canon(&want_scoped));

    // samples: per-class seen totals stay exact, every drawn instance is
    // genuine, and a fixed seed draws the identical sample twice
    let mut want_seen: BTreeMap<u16, u64> = BTreeMap::new();
    for (cid, &seen) in want.class_ids.iter().zip(&want.per_class_seen) {
        want_seen.insert(*cid, seen);
    }
    let all: BTreeSet<(Vec<u32>, u16)> = canon(&want).into_iter().collect();
    let sq = MotifQuery {
        size,
        direction,
        output: Output::Sample { per_class: 4, seed: 9 },
        ..Default::default()
    };
    let draw = || match cluster
        .router
        .handle(Request::Sample { graph: "g".into(), query: sq.clone() }, None)
        .unwrap()
    {
        Response::Sampled { sample, .. } => sample,
        other => panic!("{other:?}"),
    };
    let s1 = draw();
    let s2 = draw();
    assert_eq!(s1.total_seen, want.total_seen);
    assert_eq!(s1.classes.len(), s2.classes.len());
    for (c1, c2) in s1.classes.iter().zip(&s2.classes) {
        assert_eq!(c1.class_id, c2.class_id);
        assert_eq!(c1.seen, want_seen.get(&c1.class_id).copied().unwrap_or(0), "m{}", c1.class_id);
        assert!(c1.instances.len() <= 4, "m{} over-drew", c1.class_id);
        assert_eq!(c1.instances.len() as u64, c1.seen.min(4), "m{}", c1.class_id);
        for inst in &c1.instances {
            assert!(
                all.contains(&(inst.verts.clone(), c1.class_id)),
                "sampled non-instance {:?} (m{})",
                inst.verts,
                c1.class_id
            );
        }
        // determinism for a fixed seed
        let v1: Vec<&Vec<u32>> = c1.instances.iter().map(|i| &i.verts).collect();
        let v2: Vec<&Vec<u32>> = c2.instances.iter().map(|i| &i.verts).collect();
        assert_eq!(v1, v2, "m{} resampled differently", c1.class_id);
    }
}

#[test]
fn delta_batches_keep_the_cluster_exact_across_rounds() {
    // k_max 4 so the replicated fringe is radius 3; three sequential
    // batches exercise the fetch-ball invariant, not just the plan-time
    // static fringe
    let g = generators::gnp_undirected(48, 0.09, 21);
    let n = g.n() as u32;
    let cluster = Cluster::must_start(&g, "g", 4, 2);
    let mut oracle = Session::load(&g);
    let q3 = query(3, Direction::Undirected);
    let q4 = query(4, Direction::Undirected);

    for round in 0..3u32 {
        let deltas = delta_batch(n, round);
        let want = oracle.apply_edges(&deltas).unwrap();
        let got = match cluster
            .router
            .handle(Request::ApplyEdges { graph: "g".into(), deltas: deltas.clone() }, None)
            .unwrap()
        {
            Response::Applied { report, .. } => report,
            other => panic!("{other:?}"),
        };
        // the authoritative accounting matches the oracle exactly: the
        // owner of each delta's minimal endpoint always has both
        // endpoints' true adjacency within its fringe (per-shard
        // touched/re-enumerated tallies are workload metrics, not
        // merged here)
        assert_eq!(got.inserted, want.inserted, "round {round}");
        assert_eq!(got.deleted, want.deleted, "round {round}");
        assert_eq!(got.skipped_duplicate, want.skipped_duplicate, "round {round}");
        assert_eq!(got.skipped_missing, want.skipped_missing, "round {round}");
        assert_eq!(got.skipped_invalid, want.skipped_invalid, "round {round}");

        // post-batch enumeration stays bit-identical, both sizes
        for q in [&q3, &q4] {
            let want = oracle.count(q).unwrap();
            let got = cluster.count(q);
            assert_eq!(got.per_vertex, want.per_vertex, "round {round} k={}", want.k);
            assert_eq!(got.total_instances, want.total_instances, "round {round}");
        }
    }
}

#[test]
fn a_dead_worker_is_a_typed_error_and_healthy_shards_keep_serving() {
    let g = generators::gnp_undirected(60, 0.08, 13);
    let oracle = Session::load(&g);
    let mut cluster = Cluster::must_start(&g, "g", 3, 2);
    let q = query(3, Direction::Undirected);
    let want = oracle.count(&q).unwrap();
    assert_eq!(cluster.count(&q).per_vertex, want.per_vertex, "healthy cluster first");

    let dead = 1usize;
    let split = cluster.router.plan().shards[0].v_end;
    cluster.kill(dead);

    // a full count needs every shard: the failure is typed and names the
    // dead shard — never a wrong or hung answer
    let err = cluster
        .router
        .handle(Request::Count { graph: "g".into(), query: q.clone() }, None)
        .unwrap_err();
    let shard_err = err
        .downcast_ref::<ShardError>()
        .unwrap_or_else(|| panic!("untyped worker-loss error: {err:#}"));
    assert_eq!(shard_err.shard, dead, "{shard_err}");

    // rows owned entirely by the surviving shard still serve, exactly
    let probe: Vec<u32> = vec![0, 1, split.saturating_sub(1)];
    match cluster
        .router
        .handle(
            Request::VertexCounts {
                graph: "g".into(),
                size: q.size,
                direction: q.direction,
                scope: Scope::Vertices(probe.clone()),
            },
            None,
        )
        .unwrap()
    {
        Response::VertexRows { rows, .. } => {
            assert_eq!(rows.len(), probe.len());
            for r in &rows {
                assert_eq!(r.counts, want.vertex(r.vertex), "v{}", r.vertex);
            }
        }
        other => panic!("{other:?}"),
    }

    // rows owned by the dead shard fail typed, and the failure still
    // names it
    let err = cluster
        .router
        .handle(
            Request::VertexCounts {
                graph: "g".into(),
                size: q.size,
                direction: q.direction,
                scope: Scope::Vertices(vec![split]),
            },
            None,
        )
        .unwrap_err();
    assert_eq!(err.downcast_ref::<ShardError>().map(|e| e.shard), Some(dead), "{err:#}");
}

#[test]
fn a_service_mounted_router_owns_its_plan_graph_and_leaves_the_pool_alone() {
    let g = generators::gnp_undirected(50, 0.09, 17);
    let oracle = Session::load(&g);
    let cluster = Cluster::must_start(&g, "web", 3, 2);
    // second router over the same live workers, mounted behind a service
    let router = Router::connect(cluster.router.plan().clone()).unwrap();
    let svc = VdmcService::with_router(ServiceConfig::default(), router);

    // the plan graph scatters
    let q = query(3, Direction::Undirected);
    match svc.handle(Request::Count { graph: "web".into(), query: q.clone() }).unwrap() {
        Response::Counted { counts, .. } => {
            assert_eq!(counts.per_vertex, oracle.count(&q).unwrap().per_vertex);
        }
        other => panic!("{other:?}"),
    }

    // non-routable ops naming the plan graph are rejected, not served
    // from (or loaded into) the local pool
    for req in [
        Request::Maintain {
            graph: "web".into(),
            size: q.size,
            direction: q.direction,
            output: Output::Counts,
        },
        Request::Evict { graph: "web".into() },
        Request::LoadGraph { graph: "web".into(), source: edges_source(&g), directed: false },
    ] {
        let op = req.op();
        assert!(svc.handle(req).is_err(), "{op} on the plan graph must be refused");
    }

    // other graph ids still serve from the local pool, and ping stays a
    // plain local answer
    svc.handle(Request::LoadGraph {
        graph: "local".into(),
        source: edges_source(&g),
        directed: false,
    })
    .unwrap();
    match svc.handle(Request::Count { graph: "local".into(), query: q.clone() }).unwrap() {
        Response::Counted { counts, .. } => {
            assert_eq!(counts.total_instances, oracle.count(&q).unwrap().total_instances);
        }
        other => panic!("{other:?}"),
    }
    match svc.handle(Request::Ping).unwrap() {
        Response::Pong { shard, .. } => assert_eq!(shard, None),
        other => panic!("{other:?}"),
    }
}
