//! Cancellation purity: an aborted enumeration must leave the service
//! in a state bit-identical to the query never having run — no epoch
//! bump, no byte growth, no leaked snapshot pins — and a re-issue of
//! the same query must match a dedicated-session oracle exactly.
//!
//! The abort itself is made deterministic with the fault harness: a
//! graph-scoped `enumerate_unit` delay stretches work units so a short
//! deadline (or a cross-thread cancel) always lands mid-run. This is an
//! integration test on purpose — it owns its process, so the
//! process-global fault registry can't race the lib tests (the faults
//! are still graph-scoped and cleared, out of the same caution).

use std::time::{Duration, Instant};

use vdmc::engine::{AbortReason, CancelToken, CountQuery, QueryAborted, Session};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::service::{faults, GraphSource, Request, Response, VdmcService};

fn load_req(id: &str, g: &Graph) -> Request {
    Request::LoadGraph {
        graph: id.to_string(),
        source: GraphSource::Edges { n: g.n(), edges: g.out.edges().collect() },
        directed: true,
    }
}

/// Everything observable about the pool that a pure abort must not
/// change: entry count, byte accounting, leaked pins, and each resident
/// graph's (id, epoch, bytes) line.
fn pool_fingerprint(svc: &VdmcService) -> (usize, usize, usize, usize, Vec<(String, u64, usize)>) {
    match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool, .. } => (
            pool.entries,
            pool.resident_bytes,
            pool.retained_bytes,
            pool.pinned_snapshots,
            pool.graphs.iter().map(|g| (g.id.clone(), g.epoch, g.bytes)).collect(),
        ),
        other => panic!("{other:?}"),
    }
}

fn abort_of(err: &anyhow::Error) -> &QueryAborted {
    err.downcast_ref::<QueryAborted>()
        .unwrap_or_else(|| panic!("expected a typed QueryAborted, got: {err:#}"))
}

#[test]
fn deadline_abort_leaves_no_trace_and_reissue_matches_oracle() {
    let svc = VdmcService::with_defaults();
    for seed in 0..3u64 {
        let id = format!("purity-{seed}");
        let g = generators::gnp_directed(60, 0.08, seed + 101);
        svc.handle(load_req(&id, &g)).unwrap();
        let before = pool_fingerprint(&svc);

        // stretch every work unit by 30ms against an 8ms budget: the
        // deadline always expires before the enumeration can finish
        faults::arm(faults::SITE_ENUMERATE_UNIT, "delay", 30, 3, Some(id.clone())).unwrap();
        let token = CancelToken::new()
            .child(Some(Instant::now() + Duration::from_millis(8)), Some(id.clone()));
        let (result, _, _) = svc.handle_cancel(
            Request::Count { graph: id.clone(), query: CountQuery::default() },
            None,
            Some(token),
        );
        let err = result.expect_err("the deadline must abort the count");
        let aborted = abort_of(&err);
        assert_eq!(aborted.reason, AbortReason::Deadline);
        assert!(
            aborted.units_done < aborted.units_total || aborted.units_total == 0,
            "an aborted run must not have finished: {aborted}"
        );
        faults::arm(faults::SITE_ENUMERATE_UNIT, "clear", 0, 0, Some(id.clone())).unwrap();

        // purity: the pool looks exactly like the query never ran
        assert_eq!(pool_fingerprint(&svc), before, "aborted seed {seed} left a trace");

        // the re-issue (no deadline) matches a dedicated session oracle
        let counts = match svc
            .handle(Request::Count { graph: id.clone(), query: CountQuery::default() })
            .unwrap()
        {
            Response::Counted { counts, .. } => counts,
            other => panic!("{other:?}"),
        };
        let want = Session::load(&g).count(&CountQuery::default()).unwrap();
        assert_eq!(counts.per_vertex, want.per_vertex, "seed {seed}");
        assert_eq!(counts.total_instances, want.total_instances, "seed {seed}");
    }

    // the three aborts are visible in the service metrics
    let text = match svc.handle(Request::Metrics).unwrap() {
        Response::Metrics { text } => text,
        other => panic!("{other:?}"),
    };
    assert!(
        text.contains("vdmc_deadline_exceeded_total 3"),
        "deadline aborts must be counted:\n{text}"
    );
}

#[test]
fn cross_thread_cancel_aborts_mid_run_with_the_given_reason() {
    let svc = VdmcService::with_defaults();
    let id = "purity-gone".to_string();
    let g = generators::gnp_directed(60, 0.08, 7);
    svc.handle(load_req(&id, &g)).unwrap();
    let before = pool_fingerprint(&svc);

    faults::arm(faults::SITE_ENUMERATE_UNIT, "delay", 20, 50, Some(id.clone())).unwrap();
    let token = CancelToken::new().child(None, Some(id.clone()));
    let canceller = {
        let token = token.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            token.cancel(AbortReason::ClientGone);
        })
    };
    let (result, _, _) = svc.handle_cancel(
        Request::Count { graph: id.clone(), query: CountQuery::default() },
        None,
        Some(token),
    );
    canceller.join().unwrap();
    faults::arm(faults::SITE_ENUMERATE_UNIT, "clear", 0, 0, Some(id.clone())).unwrap();

    let err = result.expect_err("the cross-thread cancel must abort the count");
    assert_eq!(abort_of(&err).reason, AbortReason::ClientGone);
    assert_eq!(pool_fingerprint(&svc), before, "the abort left a trace");

    // a clean re-issue still matches the oracle
    let counts = match svc
        .handle(Request::Count { graph: id.clone(), query: CountQuery::default() })
        .unwrap()
    {
        Response::Counted { counts, .. } => counts,
        other => panic!("{other:?}"),
    };
    let want = Session::load(&g).count(&CountQuery::default()).unwrap();
    assert_eq!(counts.per_vertex, want.per_vertex);
    let text = match svc.handle(Request::Metrics).unwrap() {
        Response::Metrics { text } => text,
        other => panic!("{other:?}"),
    };
    assert!(
        text.contains("vdmc_cancelled_total{reason=\"client_gone\"} 1"),
        "the cancel must be counted by reason:\n{text}"
    );
}
