//! Coordinator-focused integration tests: queue accounting, determinism
//! under contention, failure handling, and the CLI surface.

use std::process::Command;

use vdmc::coordinator::work::{build_queue, total_units, WorkQueue};
use vdmc::coordinator::{count_motifs, count_motifs_with_report, CountConfig};
use vdmc::graph::generators;
use vdmc::motifs::counter::CounterMode;
use vdmc::motifs::{Direction, MotifSize};

#[test]
fn determinism_across_repeat_runs_under_contention() {
    let g = generators::barabasi_albert(800, 4, 13);
    let cfg = CountConfig {
        size: MotifSize::Four,
        direction: Direction::Undirected,
        workers: 8,
        counter: CounterMode::Atomic,
        ..Default::default()
    };
    let first = count_motifs(&g, &cfg).unwrap();
    for _ in 0..3 {
        let again = count_motifs(&g, &cfg).unwrap();
        assert_eq!(first.per_vertex, again.per_vertex);
        assert_eq!(first.total_instances, again.total_instances);
    }
}

#[test]
fn queue_units_equal_undirected_edges_for_many_graphs() {
    for seed in 0..10u64 {
        let g = generators::gnp_undirected(200, 0.05, seed);
        let items = build_queue(&g, 16);
        assert_eq!(total_units(&items), g.und.m() / 2, "seed {seed}");
    }
}

#[test]
fn heavy_hub_split_across_items() {
    // one massive hub: its units must spread over many queue items so a
    // worker pool can share it (the paper's GPU-blocks argument)
    let g = generators::star(5000);
    let items = build_queue(&g, 32);
    let hub_items = items.iter().filter(|i| i.root == 0).count();
    assert!(hub_items >= 4999 / 32, "hub not split: {hub_items} items");
    let q = WorkQueue::new(items);
    // drain from several threads and count
    let drained: usize = std::thread::scope(|s| {
        (0..4)
            .map(|_| {
                s.spawn(|| {
                    let mut n = 0;
                    while q.pop().is_some() {
                        n += 1;
                    }
                    n
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum()
    });
    assert_eq!(drained, 5000usize.div_ceil(32).max(4999 / 32 + 1));
}

#[test]
fn report_throughput_and_imbalance_are_sane() {
    let g = generators::gnp_undirected(400, 0.05, 3);
    let (c, report) = count_motifs_with_report(
        &g,
        &CountConfig {
            size: MotifSize::Four,
            direction: Direction::Undirected,
            workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.throughput() > 0.0);
    assert!(report.imbalance() >= 1.0);
    assert_eq!(report.total_instances, c.total_instances);
    let json = report.to_json().to_string_compact();
    assert!(json.contains("\"throughput_per_sec\""));
}

#[test]
fn error_paths() {
    // directed counting on an undirected graph must fail cleanly
    let g = generators::star(10);
    let err = count_motifs(
        &g,
        &CountConfig { direction: Direction::Directed, ..Default::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("undirected"));
}

fn vdmc_bin() -> Option<std::path::PathBuf> {
    // target/release/vdmc relative to the test binary
    let exe = std::env::current_exe().ok()?;
    let dir = exe.parent()?.parent()?; // target/release
    let bin = dir.join("vdmc");
    bin.exists().then_some(bin)
}

#[test]
fn cli_generate_count_roundtrip() {
    let Some(bin) = vdmc_bin() else {
        eprintln!("skipping: vdmc binary not built (run cargo build --release first)");
        return;
    };
    let dir = std::env::temp_dir().join(format!("vdmc_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let graph_path = dir.join("g.tsv");

    let out = Command::new(&bin)
        .args(["generate", "--model", "gnp", "--n", "200", "--p", "0.05", "--directed", "--seed", "9"])
        .arg("--out")
        .arg(&graph_path)
        .output()
        .expect("run generate");
    assert!(out.status.success(), "generate failed: {}", String::from_utf8_lossy(&out.stderr));

    let out = Command::new(&bin)
        .args(["count", "--k", "3", "--directed"])
        .arg("--input")
        .arg(&graph_path)
        .output()
        .expect("run count");
    assert!(out.status.success(), "count failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.lines().any(|l| l.starts_with('m')), "no class totals printed: {stdout}");

    // baseline flag agrees with the default path on the same file
    let naive = Command::new(&bin)
        .args(["count", "--k", "3", "--directed", "--baseline-naive"])
        .arg("--input")
        .arg(&graph_path)
        .output()
        .expect("run naive count");
    assert!(naive.status.success());
    assert_eq!(String::from_utf8_lossy(&naive.stdout), stdout, "baseline disagrees with vdmc");

    // info subcommand emits JSON
    let info = Command::new(&bin)
        .args(["info", "--directed"])
        .arg("--input")
        .arg(&graph_path)
        .output()
        .expect("run info");
    assert!(info.status.success());
    assert!(String::from_utf8_lossy(&info.stdout).contains("\"mean_degree\""));

    // unknown subcommand fails with usage
    let bad = Command::new(&bin).arg("bogus").output().expect("run bogus");
    assert!(!bad.status.success());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_validate_smoke() {
    let Some(bin) = vdmc_bin() else {
        eprintln!("skipping: vdmc binary not built");
        return;
    };
    let out = Command::new(&bin)
        .args(["validate", "--n", "300", "--p", "0.05", "--k", "3", "--directed", "--json"])
        .output()
        .expect("run validate");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"chi2\""));
    assert!(stdout.contains("\"observed\""));
}
