//! Snapshot-isolation property tests: epoch stamping, pinned-snapshot
//! immutability against a reload oracle, concurrent readers racing a
//! committing writer, and the pool's refusal to free pinned state.
//!
//! The contract under test: `Session::snapshot()` pins an immutable
//! epoch-stamped view; every effective `apply_edges` batch commits a new
//! epoch at the head without touching pinned snapshots; a pinned
//! snapshot's counts are bit-identical to a fresh `Session::load` of the
//! graph as it stood at that epoch; and `SessionPool` never evicts an
//! entry whose snapshots are still pinned (it defers and reports).

use std::sync::atomic::{AtomicBool, Ordering};

use vdmc::engine::{CountQuery, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::service::SessionPool;
use vdmc::stream::EdgeDelta;

fn small_graph(seed: u64) -> Graph {
    generators::gnp_directed(60, 0.08, seed)
}

/// Deterministic effective batch: inserts fresh edges, so every round
/// changes the graph and must commit a new epoch.
fn insert_batch(g: &Graph, round: u32) -> Vec<EdgeDelta> {
    let n = g.n() as u32;
    (0..6u32)
        .map(|i| {
            let a = (i * 17 + round * 29 + 1) % n;
            let b = (i * 23 + round * 41 + 2) % n;
            EdgeDelta::insert(a, if a == b { (b + 1) % n } else { b })
        })
        .collect()
}

#[test]
fn epochs_stamp_every_effective_commit() {
    let g = small_graph(3);
    let mut session = Session::load(&g);
    assert_eq!(session.epoch(), 0, "a fresh load is epoch 0");
    assert_eq!(session.snapshot().epoch(), 0);

    let cell = session.share();
    for round in 0..4u32 {
        let before = session.epoch();
        let report = session.apply_edges(&insert_batch(&session.snapshot_graph(), round)).unwrap();
        assert!(report.applied() > 0, "round {round} must be effective");
        assert_eq!(session.epoch(), before + 1, "each effective batch commits one epoch");
        assert_eq!(cell.epoch(), session.epoch(), "the shared cell tracks the head");
        assert_eq!(cell.head().epoch(), session.epoch());
    }

    // a batch that applies nothing commits nothing
    let before = session.epoch();
    let report = session.apply_edges(&[]).unwrap();
    assert_eq!(report.applied(), 0);
    assert_eq!(session.epoch(), before, "empty batches don't mint epochs");
}

#[test]
fn pinned_snapshots_are_bit_identical_to_a_reload_at_their_epoch() {
    let g = small_graph(7);
    let mut session = Session::load(&g);
    session.maintain(MotifSize::Three, Direction::Directed).unwrap();

    let q3 = CountQuery::default();
    let q4 = CountQuery { size: MotifSize::Four, ..Default::default() };

    // pin the current epoch (maintain committed one), remember its
    // graph and counts
    let pinned = session.snapshot();
    let pinned_epoch = pinned.epoch();
    let pinned_graph = pinned.snapshot_graph();
    let before3 = pinned.count(&q3).unwrap();
    let before4 = pinned.count(&q4).unwrap();

    // the writer moves on: several committed epochs
    for round in 0..3u32 {
        session.apply_edges(&insert_batch(&session.snapshot_graph(), round)).unwrap();
    }
    assert_eq!(pinned.epoch(), pinned_epoch, "the pin stays at its epoch");
    assert!(session.epoch() > pinned_epoch);

    // the pinned view still answers exactly as its epoch did: the oracle
    // is a dedicated session loaded from the graph as pinned
    let oracle = Session::load(&pinned_graph);
    for (q, before) in [(&q3, &before3), (&q4, &before4)] {
        let again = pinned.count(q).unwrap();
        assert_eq!(again.per_vertex, before.per_vertex, "pinned counts are frozen");
        let want = oracle.count(q).unwrap();
        assert_eq!(again.per_vertex, want.per_vertex, "pinned == reload at pinned epoch");
        assert_eq!(again.total_instances, want.total_instances);
    }
    // maintained rows on the pin are frozen too
    let row0 = pinned.maintained_vertex(MotifSize::Three, Direction::Directed, 0).unwrap();
    let oracle3 = oracle.count(&q3).unwrap();
    assert_eq!(row0, oracle3.vertex(0));

    // while the head answers for the mutated graph, same oracle scheme
    let head = session.snapshot();
    let fresh = Session::load(&head.snapshot_graph());
    let got = head.count(&q3).unwrap();
    let want = fresh.count(&q3).unwrap();
    assert_eq!(got.per_vertex, want.per_vertex, "head == reload at head epoch");
}

/// The tentpole's race: scoped readers pinning snapshots while a writer
/// thread commits batch after batch. Every reader observation must be
/// internally consistent — the counts of the epoch it pinned, verified
/// against a dedicated reload of that epoch's graph.
#[test]
fn concurrent_readers_race_a_committing_writer() {
    let g = small_graph(13);
    let mut session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
    let cell = session.share();
    let q3 = CountQuery::default();

    let writer_done = AtomicBool::new(false);
    std::thread::scope(|s| {
        // the writer: 6 committed epochs, no coordination with readers
        s.spawn(|| {
            for round in 0..6u32 {
                let batch = insert_batch(&session.snapshot_graph(), round);
                session.apply_edges(&batch).unwrap();
            }
            writer_done.store(true, Ordering::SeqCst);
        });
        // readers: pin whatever head is current, count, and hold the
        // result to the reload oracle of exactly that pinned epoch
        for r in 0..3usize {
            let cell = &cell;
            let q3 = &q3;
            let writer_done = &writer_done;
            s.spawn(move || {
                let mut checked = 0usize;
                loop {
                    let snap = cell.head();
                    let epoch = snap.epoch();
                    let got = snap.count(q3).unwrap();
                    // the pin holds even if the writer commits right now
                    let oracle = Session::load(&snap.snapshot_graph());
                    let want = oracle.count(q3).unwrap();
                    assert_eq!(
                        got.per_vertex, want.per_vertex,
                        "reader {r}: epoch {epoch} diverged from its reload oracle"
                    );
                    assert_eq!(snap.epoch(), epoch, "the pinned epoch never moves");
                    checked += 1;
                    if writer_done.load(Ordering::SeqCst) && checked >= 3 {
                        break;
                    }
                }
            });
        }
    });

    assert_eq!(cell.epoch(), 6, "all writer commits landed");
}

#[test]
fn pool_defers_eviction_of_pinned_snapshots() {
    // entry cap 1: inserting "b" wants to evict "a", but "a" is pinned
    let mut pool = SessionPool::new(1, 0);
    pool.insert("a", Session::load(&small_graph(1)));
    let pin = pool.pin("a").expect("a is resident");
    pool.insert("b", Session::load(&small_graph(2)));

    let stats = pool.stats();
    assert!(pool.contains("a"), "pinned entries must never be freed");
    assert_eq!(stats.entries, 2, "over cap because the victim was pinned");
    assert!(stats.evictions_deferred >= 1, "the deferral is reported: {stats:?}");
    assert!(stats.pinned_snapshots >= 1);

    // counting through the pin keeps working even while the pool is
    // over budget — the query can't have its state freed underneath it
    let counts = pin.count(&CountQuery::default()).unwrap();
    let want = Session::load(&small_graph(1)).count(&CountQuery::default()).unwrap();
    assert_eq!(counts.per_vertex, want.per_vertex);

    // releasing the pin makes "a" evictable again on the next pressure
    drop(pin);
    pool.insert("c", Session::load(&small_graph(3)));
    let stats = pool.stats();
    assert!(stats.entries <= 2, "unpinned entries evict normally: {stats:?}");
    assert!(pool.contains("c"));
}
