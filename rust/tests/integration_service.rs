//! Service-layer acceptance: pooled multi-graph traffic must be
//! bit-identical to dedicated per-graph sessions — across interleaved
//! queries, live edge deltas, byte-budget evictions, the JSONL wire,
//! and the serve transports (EOF drain, malformed-line ordering, TCP
//! multi-client).

use vdmc::engine::{CountQuery, Scope, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::service::{
    serve_connection, serve_tcp, wire, GraphSource, Request, Response, ServeOptions,
    ServiceConfig, VdmcService,
};
use vdmc::stream::EdgeDelta;
use vdmc::util::json::Json;

fn edges_of(g: &Graph) -> Vec<(u32, u32)> {
    g.out.edges().collect()
}

fn graphs() -> Vec<(String, Graph)> {
    (0..3u64)
        .map(|s| (format!("g{s}"), generators::gnp_directed(40 + 5 * s as usize, 0.08, s + 11)))
        .collect()
}

fn load_req(id: &str, g: &Graph) -> Request {
    Request::LoadGraph {
        graph: id.to_string(),
        source: GraphSource::Edges { n: g.n(), edges: edges_of(g) },
        directed: true,
    }
}

/// Deterministic per-(graph, round) delta batch, valid vertex range `n`.
fn delta_batch(n: usize, round: u64) -> Vec<EdgeDelta> {
    let n = n as u32;
    (0..8u32)
        .flat_map(|i| {
            let a = (i * 7 + round as u32 * 13 + 1) % n;
            let b = (i * 11 + round as u32 * 5 + 2) % n;
            [EdgeDelta::insert(a, b), EdgeDelta::delete((a + 3) % n, (b + 1) % n)]
        })
        .filter(|d| d.u != d.v)
        .collect()
}

/// The acceptance property: interleaved traffic over 3 pooled graphs,
/// including apply_edges batches, stays bit-identical to 3 dedicated
/// sessions fed the same queries and deltas — and the pool reports the
/// reuse as hits.
#[test]
fn interleaved_pooled_traffic_matches_dedicated_sessions() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    let mut oracles: Vec<Session> = Vec::new();
    for (id, g) in &graphs {
        svc.handle(load_req(id, g)).unwrap();
        oracles.push(Session::load_with(g, &SessionConfig::default()));
    }

    let q3 = CountQuery::default();
    let q4 = CountQuery { size: MotifSize::Four, ..Default::default() };
    for round in 0..3u64 {
        for (gi, (id, g)) in graphs.iter().enumerate() {
            // full counts, both sizes, straight against the dedicated oracle
            for q in [&q3, &q4] {
                let got = match svc
                    .handle(Request::Count { graph: id.clone(), query: q.clone() })
                    .unwrap()
                {
                    Response::Counted { counts, .. } => counts,
                    other => panic!("{other:?}"),
                };
                let want = oracles[gi].count(q).unwrap();
                assert_eq!(got.per_vertex, want.per_vertex, "{id} round {round} {:?}", q.size);
                assert_eq!(got.total_instances, want.total_instances);
            }

            // per-vertex lookups (maintained counters) for a fixed probe set
            let probe: Vec<u32> = vec![0, 1, (g.n() as u32) - 1];
            match svc
                .handle(Request::VertexCounts {
                    graph: id.clone(),
                    size: MotifSize::Three,
                    direction: Direction::Directed,
                    scope: Scope::Vertices(probe.clone()),
                })
                .unwrap()
            {
                Response::VertexRows { rows, total_instances, .. } => {
                    let want = oracles[gi].count(&q3).unwrap();
                    assert_eq!(total_instances, want.total_instances, "{id} round {round}");
                    for r in rows {
                        assert_eq!(
                            r.counts,
                            want.vertex(r.vertex),
                            "{id} round {round} v{}",
                            r.vertex
                        );
                    }
                }
                other => panic!("{other:?}"),
            }

            // mutate both sides identically before the next round
            let deltas = delta_batch(g.n(), round);
            let got = match svc
                .handle(Request::ApplyEdges { graph: id.clone(), deltas: deltas.clone() })
                .unwrap()
            {
                Response::Applied { report, .. } => report,
                other => panic!("{other:?}"),
            };
            let want = oracles[gi].apply_edges(&deltas).unwrap();
            assert_eq!(got.applied(), want.applied(), "{id} round {round}");
            assert_eq!(got.skipped(), want.skipped());
        }
    }

    match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool: s, process } => {
            assert_eq!(s.entries, 3);
            assert!(s.hits > 0, "interleaved traffic must be served from pooled sessions");
            assert_eq!(s.misses, 0);
            assert!(s.resident_bytes > 0);
            assert!(process.total_requests() > 0, "traffic shows up in the process counters");
        }
        other => panic!("{other:?}"),
    }
}

/// Byte-budget evictions under traffic: a budget that fits ~2 of 3
/// sessions must evict, report the cause, and reloading the victim must
/// still produce bit-identical counts.
#[test]
fn byte_budget_eviction_is_reported_and_recoverable() {
    let graphs = graphs();
    let per: usize = graphs
        .iter()
        .map(|(_, g)| Session::load_with(g, &SessionConfig::default()).memory_bytes())
        .max()
        .unwrap();
    // two largest-session budget: the three graphs (n = 40/45/50) sum
    // well past it, so the third load must evict
    let svc = VdmcService::new(ServiceConfig {
        max_graphs: 0,
        byte_budget: per * 2,
        ..Default::default()
    });
    for (id, g) in &graphs {
        svc.handle(load_req(id, g)).unwrap();
    }
    let stats = match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool, .. } => pool,
        other => panic!("{other:?}"),
    };
    assert!(
        stats.evictions_byte_budget >= 1,
        "3 sessions into a 2.5-session budget must evict: {stats:?}"
    );
    assert!(stats.entries < 3);

    // the evicted graph is simply a miss: reload and serve, bit-identical
    let victim = graphs
        .iter()
        .find(|(id, _)| {
            svc.handle(Request::Count { graph: id.clone(), query: CountQuery::default() })
                .is_err()
        })
        .expect("some graph was evicted");
    svc.handle(load_req(&victim.0, &victim.1)).unwrap();
    let got = match svc
        .handle(Request::Count { graph: victim.0.clone(), query: CountQuery::default() })
        .unwrap()
    {
        Response::Counted { counts, .. } => counts,
        other => panic!("{other:?}"),
    };
    let want = Session::load(&victim.1).count(&CountQuery::default()).unwrap();
    assert_eq!(got.per_vertex, want.per_vertex);

    let stats = match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool, .. } => pool,
        other => panic!("{other:?}"),
    };
    assert!(stats.misses >= 1, "the evicted graph's query must count as a miss");
    assert!(stats.hits >= 1);
}

/// End-to-end wire exercise of the `vdmc serve` loop body: an
/// interleaved JSONL stream over 3 graphs, every response line parses,
/// and counts match dedicated sessions exactly.
#[test]
fn wire_jsonl_stream_matches_dedicated_sessions() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();

    // the serve loop body, minus stdin plumbing
    let roundtrip = |line: String| -> Json {
        let (req, id, trace, _) =
            wire::decode_request(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        let op = req.op();
        let (result, secs, trace_id) = svc.handle_traced(req, trace);
        let reply = match result {
            Ok(resp) => wire::encode_response(&resp, id, secs, Some(&trace_id)),
            Err(e) => wire::encode_error(Some(op), id, Some(&trace_id), &format!("{e:#}")),
        };
        Json::parse(&reply).unwrap_or_else(|e| panic!("unparseable response {reply}: {e}"))
    };

    // load all three graphs over the wire (inline edges)
    for (i, (id, g)) in graphs.iter().enumerate() {
        let edges: Vec<String> =
            edges_of(g).iter().map(|(u, v)| format!("[{u},{v}]")).collect();
        let line = format!(
            r#"{{"op":"load_graph","id":{i},"graph":"{id}","directed":true,"n":{},"edges":[{}]}}"#,
            g.n(),
            edges.join(",")
        );
        let j = roundtrip(line);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(i as u64));
        assert_eq!(j.get("m").and_then(Json::as_usize), Some(g.m()));
    }

    for (id, g) in &graphs {
        let oracle = Session::load(g);
        let want = oracle.count(&CountQuery::default()).unwrap();

        // class-total digest over the wire
        let j = roundtrip(format!(
            r#"{{"op":"count","graph":"{id}","k":3,"direction":"directed"}}"#
        ));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
        assert_eq!(
            j.get("total_instances").and_then(Json::as_u64),
            Some(want.total_instances),
            "{id}"
        );
        let classes = j.get("classes").expect("classes digest");
        for (cid, t) in want.class_ids.iter().zip(want.class_instances()) {
            assert_eq!(
                classes.get(&format!("m{cid}")).and_then(Json::as_u64),
                Some(t),
                "{id} class m{cid}"
            );
        }

        // instances over the wire: untruncated, exact totals
        let j = roundtrip(format!(
            r#"{{"op":"instances","graph":"{id}","k":3,"direction":"directed","limit":1000000}}"#
        ));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
        assert_eq!(
            j.get("total_seen").and_then(Json::as_u64),
            Some(want.total_instances),
            "{id} instances"
        );
        assert_eq!(j.get("truncated").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("count").and_then(Json::as_u64),
            Some(want.total_instances),
            "{id} materialized"
        );

        // sample over the wire: per-class seen equals the class digest
        let j = roundtrip(format!(
            r#"{{"op":"sample","graph":"{id}","k":3,"direction":"directed","per_class":4,"seed":9}}"#
        ));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
        let sample_classes = j.get("classes").expect("sample classes");
        for (cid, t) in want.class_ids.iter().zip(want.class_instances()) {
            if t == 0 {
                continue; // empty classes are omitted from the sample map
            }
            let entry = sample_classes
                .get(&format!("m{cid}"))
                .unwrap_or_else(|| panic!("{id}: sample class m{cid} missing"));
            assert_eq!(entry.get("seen").and_then(Json::as_u64), Some(t), "{id} m{cid}");
            let kept = entry.get("sample").and_then(Json::as_arr).unwrap().len() as u64;
            assert_eq!(kept, t.min(4), "{id} m{cid} reservoir size");
        }

        // scoped count over the wire: a vertex scope answers with the
        // scope-touching totals only
        let j = roundtrip(format!(
            r#"{{"op":"count","graph":"{id}","k":3,"direction":"directed","vertices":[0,1]}}"#
        ));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
        assert!(
            j.get("total_instances").and_then(Json::as_u64).unwrap() <= want.total_instances,
            "{id} scoped"
        );

        // exact per-vertex rows over the wire
        let probe: Vec<u32> = (0..g.n() as u32).step_by(7).collect();
        let vs: Vec<String> = probe.iter().map(u32::to_string).collect();
        let j = roundtrip(format!(
            r#"{{"op":"vertex_counts","graph":"{id}","k":3,"direction":"directed","vertices":[{}]}}"#,
            vs.join(",")
        ));
        let counts = j.get("counts").expect("counts map");
        for v in &probe {
            let row: Vec<u64> = counts
                .get(&v.to_string())
                .and_then(Json::as_arr)
                .unwrap_or_else(|| panic!("{id}: no row for v{v}"))
                .iter()
                .map(|x| x.as_u64().unwrap())
                .collect();
            assert_eq!(row, want.vertex(*v), "{id} v{v}");
        }

        // mutate over the wire, then verify against a patched oracle
        let j = roundtrip(format!(
            r#"{{"op":"apply_edges","graph":"{id}","deltas":[["+",0,3],["+",3,5],["-",1,2]]}}"#
        ));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        let mut oracle = Session::load(g);
        oracle
            .apply_edges(&[EdgeDelta::insert(0, 3), EdgeDelta::insert(3, 5), EdgeDelta::delete(1, 2)])
            .unwrap();
        let want = oracle.count(&CountQuery::default()).unwrap();
        let j = roundtrip(format!(
            r#"{{"op":"count","graph":"{id}","k":3,"direction":"directed"}}"#
        ));
        assert_eq!(
            j.get("total_instances").and_then(Json::as_u64),
            Some(want.total_instances),
            "{id} after deltas"
        );
    }

    // errors come back as ok:false lines and the daemon keeps serving
    let j = roundtrip(r#"{"op":"count","graph":"ghost","id":99}"#.to_string());
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(j.get("id").and_then(Json::as_u64), Some(99));
    assert!(j.get("error").and_then(Json::as_str).unwrap().contains("not loaded"));

    let j = roundtrip(r#"{"op":"stats"}"#.to_string());
    let pool = j.get("pool").expect("pool stats");
    assert!(pool.get("hits").and_then(Json::as_u64).unwrap() > 0);
    assert_eq!(pool.get("entries").and_then(Json::as_usize), Some(3));
}

fn response_lines(out: &[u8]) -> Vec<Json> {
    std::str::from_utf8(out).unwrap().lines().map(|l| Json::parse(l).unwrap()).collect()
}

/// Shutdown regression: EOF on the request stream must drain every
/// in-flight response before `serve_connection` returns — even when the
/// handler runs far ahead of a tiny inflight window, no tail of handled
/// requests may lose its reply.
#[test]
fn serve_eof_drains_inflight_responses() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    svc.handle(load_req(&graphs[0].0, &graphs[0].1)).unwrap();
    let want = Session::load(&graphs[0].1).count(&CountQuery::default()).unwrap();

    let mut input = String::new();
    for i in 0..24 {
        input.push_str(&format!(
            "{{\"op\":\"count\",\"id\":{i},\"graph\":\"g0\",\"k\":3,\"direction\":\"directed\"}}\n"
        ));
    }
    let mut out: Vec<u8> = Vec::new();
    let opts = ServeOptions { inflight: 2, ..Default::default() };
    let served = serve_connection(&svc, input.as_bytes(), &mut out, &opts).unwrap();
    assert_eq!(served, 24);

    let lines = response_lines(&out);
    assert_eq!(lines.len(), 24, "every handled request gets a drained response");
    for (i, j) in lines.iter().enumerate() {
        assert_eq!(j.get("id").and_then(Json::as_u64), Some(i as u64), "response order");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            j.get("total_instances").and_then(Json::as_u64),
            Some(want.total_instances),
            "request {i}"
        );
    }
}

/// Ordering regression: a malformed line mid-stream becomes an ok:false
/// response in its slot — later responses keep their positions and ids,
/// and handler-level errors (unknown graph) are distinct from decode
/// errors but equally in-order.
#[test]
fn serve_malformed_line_mid_stream_keeps_ordering() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    svc.handle(load_req(&graphs[0].0, &graphs[0].1)).unwrap();

    let input = "\
        {\"op\":\"stats\",\"id\":1}\n\
        this line is not json at all\n\
        {\"op\":\"count\",\"id\":2,\"graph\":\"g0\",\"k\":3,\"direction\":\"directed\"}\n\
        {\"op\":\"count\",\"id\":3,\"graph\":\"ghost\",\"k\":3,\"direction\":\"directed\"}\n\
        {\"op\":\"stats\",\"id\":4}\n";
    let mut out: Vec<u8> = Vec::new();
    let served =
        serve_connection(&svc, input.as_bytes(), &mut out, &ServeOptions::default()).unwrap();
    assert_eq!(served, 5, "malformed and failing lines still cost one response slot each");

    let lines = response_lines(&out);
    assert_eq!(lines.len(), 5);
    let ids: Vec<Option<u64>> =
        lines.iter().map(|l| l.get("id").and_then(Json::as_u64)).collect();
    assert_eq!(ids, vec![Some(1), None, Some(2), Some(3), Some(4)], "ordering preserved");
    let oks: Vec<bool> =
        lines.iter().map(|l| l.get("ok").and_then(Json::as_bool).unwrap()).collect();
    assert_eq!(oks, vec![true, false, true, false, true]);
    assert!(lines[1].get("error").and_then(Json::as_str).is_some(), "decode error reported");
    assert!(
        lines[3].get("error").and_then(Json::as_str).unwrap().contains("not loaded"),
        "handler error reported"
    );
}

/// The multi-client transport end-to-end: several TCP clients share one
/// pool, each gets its own in-order bit-exact responses, and flipping
/// the shutdown flag drains everything before `serve_tcp` returns.
#[test]
fn tcp_clients_share_one_pool_and_drain_on_shutdown() {
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::{Shutdown, TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    for (id, g) in &graphs {
        svc.handle(load_req(id, g)).unwrap();
    }
    let wants: Vec<u64> = graphs
        .iter()
        .map(|(_, g)| Session::load(g).count(&CountQuery::default()).unwrap().total_instances)
        .collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = {
        let svc = svc.clone();
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || {
            serve_tcp(&svc, listener, &ServeOptions::default(), &shutdown).unwrap()
        })
    };

    let n_clients = 4usize;
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                for (i, gid) in ["g0", "g1", "g2"].iter().enumerate() {
                    writeln!(
                        w,
                        "{{\"op\":\"count\",\"id\":{},\"graph\":\"{gid}\",\"k\":3,\
                         \"direction\":\"directed\"}}",
                        c * 10 + i
                    )
                    .unwrap();
                }
                // half-close: the server sees EOF and must drain our replies
                w.shutdown(Shutdown::Write).unwrap();
                let mut replies = Vec::new();
                let mut line = String::new();
                loop {
                    line.clear();
                    if reader.read_line(&mut line).unwrap() == 0 {
                        break;
                    }
                    replies.push(Json::parse(line.trim()).unwrap());
                }
                replies
            })
        })
        .collect();

    for (c, h) in clients.into_iter().enumerate() {
        let replies = h.join().unwrap();
        assert_eq!(replies.len(), 3, "client {c}: one drained response per request");
        for (i, j) in replies.iter().enumerate() {
            assert_eq!(j.get("id").and_then(Json::as_u64), Some((c * 10 + i) as u64));
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "client {c}: {j:?}");
            assert_eq!(
                j.get("total_instances").and_then(Json::as_u64),
                Some(wants[i]),
                "client {c} graph g{i}: pooled answer must match the dedicated oracle"
            );
        }
    }

    shutdown.store(true, Ordering::SeqCst);
    let summary = server.join().unwrap();
    assert_eq!(summary.clients, n_clients as u64);
    assert_eq!(summary.requests, (n_clients * 3) as u64);

    // one pool behind all clients: 12 pooled hits, zero reloads
    match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool: s, .. } => {
            assert_eq!(s.entries, 3);
            assert!(s.hits >= (n_clients * 3) as u64, "stats: {s:?}");
            assert_eq!(s.misses, 0);
        }
        other => panic!("{other:?}"),
    }
}

/// Trace ids and the phase breakdown survive the full JSONL round trip:
/// a client-supplied `"trace"` is echoed on the response line, the count
/// digest carries `phase_secs`, and the span lands in the trace buffer
/// under that id with the engine phases recorded.
#[test]
fn trace_and_phase_breakdown_ride_the_wire() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    svc.handle(load_req(&graphs[0].0, &graphs[0].1)).unwrap();

    let input = "\
        {\"op\":\"count\",\"id\":1,\"graph\":\"g0\",\"k\":3,\"direction\":\"directed\",\
         \"trace\":\"probe-1\"}\n\
        {\"op\":\"count\",\"id\":2,\"graph\":\"g0\",\"k\":3,\"direction\":\"directed\"}\n";
    let mut out: Vec<u8> = Vec::new();
    serve_connection(&svc, input.as_bytes(), &mut out, &ServeOptions::default()).unwrap();
    let lines = response_lines(&out);
    assert_eq!(lines.len(), 2);

    assert_eq!(lines[0].get("trace").and_then(Json::as_str), Some("probe-1"));
    let phases = lines[0].get("phase_secs").expect("count digest carries phase_secs");
    for key in ["setup", "enumerate", "merge"] {
        assert!(phases.get(key).and_then(Json::as_f64).is_some(), "phase_secs.{key}");
    }
    // no client id: the service stamps a generated one
    let generated = lines[1].get("trace").and_then(Json::as_str).unwrap();
    assert!(!generated.is_empty() && generated != "probe-1");

    // the span is findable in the trace buffer by the client's id
    let rec = svc
        .telemetry()
        .traces()
        .recent(16)
        .into_iter()
        .find(|r| r.trace_id == "probe-1")
        .expect("span buffered under the client's trace id");
    assert_eq!(rec.op, "count");
    assert_eq!(rec.graph.as_deref(), Some("g0"));
    assert!(rec.phases.iter().any(|(p, _)| *p == "enumerate"), "phases: {:?}", rec.phases);
    assert!(rec.total_secs >= 0.0);
}

/// The exposition body parses line by line: every line is a HELP/TYPE
/// header or a `name[{labels}] value` sample, histograms expand to
/// cumulative le-buckets closed by +Inf, and the families the catalog
/// guarantees are all present after real traffic.
#[test]
fn prometheus_exposition_parses_line_by_line() {
    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    svc.handle(load_req(&graphs[0].0, &graphs[0].1)).unwrap();
    let input = "\
        {\"op\":\"count\",\"id\":1,\"graph\":\"g0\",\"k\":3,\"direction\":\"directed\"}\n\
        {\"op\":\"stats\",\"id\":2}\n\
        {\"op\":\"metrics\",\"id\":3}\n";
    let mut out: Vec<u8> = Vec::new();
    serve_connection(&svc, input.as_bytes(), &mut out, &ServeOptions::default()).unwrap();

    // the wire's metrics op returns the same families --metrics-addr
    // serves (values drift between renders — they're monotonic)
    let lines = response_lines(&out);
    let body = lines[2].get("metrics").and_then(Json::as_str).unwrap().to_string();
    let fams = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.starts_with("# TYPE "))
            .map(|l| l.split_whitespace().nth(2).unwrap().to_string())
            .collect()
    };
    assert_eq!(fams(&body), fams(&svc.metrics_text()));

    let mut families: Vec<(String, String)> = Vec::new(); // (name, kind)
    let mut samples = 0usize;
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            let kind = it.next().unwrap().to_string();
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "unknown kind in {line:?}"
            );
            families.push((name, kind));
            continue;
        }
        if line.starts_with("# HELP ") {
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment {line:?}");
        // sample line: name[{labels}] value
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("{line:?}"));
        assert!(
            value == "+Inf" || value.parse::<f64>().is_ok(),
            "unparseable value in {line:?}"
        );
        let name = series.split('{').next().unwrap();
        let (fam, kind) = families
            .iter()
            .rev()
            .find(|(f, _)| {
                name == f
                    || (name.strip_prefix(f.as_str()).is_some_and(|suf| {
                        ["_bucket", "_sum", "_count"].contains(&suf)
                    }))
            })
            .unwrap_or_else(|| panic!("sample {line:?} before its TYPE header"));
        if name != fam {
            assert_eq!(kind, "histogram", "{line:?} uses histogram suffixes");
        }
        samples += 1;
    }
    assert!(samples > 0);

    // the guaranteed catalog after a count + stats round
    for needle in [
        "vdmc_requests_total",
        "vdmc_request_seconds",
        "vdmc_phase_seconds",
        "vdmc_engine_units_total",
        "vdmc_engine_instances_total",
        "vdmc_pool_hits_total",
        "vdmc_pool_misses_total",
        "vdmc_pool_loads_total",
        "vdmc_pool_evictions_total",
        "vdmc_pool_evictions_deferred_total",
        "vdmc_pool_entries",
        "vdmc_pool_resident_bytes",
        "vdmc_pool_retained_bytes",
        "vdmc_pool_pinned_snapshots",
        "vdmc_pool_graph_epoch",
        "vdmc_process_uptime_seconds",
        "vdmc_slow_queries_total",
        "vdmc_transport_connections_total",
        "vdmc_transport_inflight",
        "vdmc_transport_malformed_lines_total",
        "vdmc_transport_bytes_total",
    ] {
        assert!(
            families.iter().any(|(f, _)| f == needle),
            "family {needle} missing; have {families:?}"
        );
    }
    assert!(families.len() >= 12, "metric catalog shrank: {families:?}");

    // nonzero where traffic guarantees it
    let sample_value = |prefix: &str| -> f64 {
        body.lines()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("{prefix} sample missing"))
            .rsplit_once(' ')
            .unwrap()
            .1
            .parse()
            .unwrap()
    };
    assert!(sample_value("vdmc_requests_total{op=\"count\"}") >= 1.0);
    assert!(sample_value("vdmc_request_seconds_count{op=\"count\"}") >= 1.0);
    assert!(sample_value("vdmc_engine_units_total") >= 1.0);
}

/// Counter exactness under racing TCP clients: with 8 clients hammering
/// one pool, the request counters, transport byte tallies and connection
/// counts come out exact — nothing lost to races.
#[test]
fn telemetry_counters_exact_under_racing_tcp_clients() {
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::{Shutdown, TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let graphs = graphs();
    let svc = VdmcService::with_defaults();
    svc.handle(load_req(&graphs[0].0, &graphs[0].1)).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = {
        let svc = svc.clone();
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || {
            serve_tcp(&svc, listener, &ServeOptions::default(), &shutdown).unwrap()
        })
    };

    let n_clients = 8usize;
    let per_client = 25usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                for i in 0..per_client {
                    writeln!(
                        w,
                        "{{\"op\":\"count\",\"id\":{},\"graph\":\"g0\",\"k\":3,\
                         \"direction\":\"directed\"}}",
                        c * 1000 + i
                    )
                    .unwrap();
                }
                w.shutdown(Shutdown::Write).unwrap();
                let mut replies = 0usize;
                let mut line = String::new();
                loop {
                    line.clear();
                    if reader.read_line(&mut line).unwrap() == 0 {
                        break;
                    }
                    let j = Json::parse(line.trim()).unwrap();
                    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
                    replies += 1;
                }
                replies
            })
        })
        .collect();
    let total_replies: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total_replies, n_clients * per_client);

    shutdown.store(true, Ordering::SeqCst);
    let summary = server.join().unwrap();
    assert_eq!(summary.requests, (n_clients * per_client) as u64);

    let body = svc.metrics_text();
    let value = |prefix: &str| -> u64 {
        body.lines()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("{prefix} missing"))
            .rsplit_once(' ')
            .unwrap()
            .1
            .parse::<f64>()
            .unwrap() as u64
    };
    let want = (n_clients * per_client) as u64;
    assert_eq!(value("vdmc_requests_total{op=\"count\"}"), want);
    assert_eq!(value("vdmc_request_seconds_count{op=\"count\"}"), want);
    assert_eq!(value("vdmc_transport_connections_total"), n_clients as u64);
    assert_eq!(value("vdmc_transport_inflight"), 0, "all queues drained");
    assert!(value("vdmc_transport_bytes_total{dir=\"in\"}") > 0);
    assert!(value("vdmc_transport_bytes_total{dir=\"out\"}") > 0);
    // the registry-derived per-op digest agrees with the same histograms
    match svc.handle(Request::Stats).unwrap() {
        Response::Stats { pool, .. } => {
            let count_op = pool.ops.iter().find(|o| o.op == "count").unwrap();
            assert_eq!(count_op.count, want);
        }
        other => panic!("{other:?}"),
    }
}
