//! Property tests for the graph substrate (CSR, ordering, IO, generators)
//! and the toolbox measures.

use vdmc::graph::csr::{Csr, Graph};
use vdmc::graph::ordering::VertexOrdering;
use vdmc::graph::{generators, io};
use vdmc::toolbox::{distance, kcore, pagerank};
use vdmc::util::prop::{check, Config, EdgeListGen, RandomEdges};

fn graph_of(re: &RandomEdges) -> Graph {
    Graph::from_edges(re.n, &re.edges, re.directed)
}

fn gen() -> EdgeListGen {
    EdgeListGen { n_lo: 2, n_hi: 24, p: 0.2, directed: true }
}

#[test]
fn csr_has_edge_matches_edge_list() {
    check("csr membership", Config::default(), &gen(), |re| {
        let csr = Csr::from_edges(re.n, &re.edges, false);
        let set: std::collections::HashSet<(u32, u32)> =
            re.edges.iter().cloned().filter(|&(u, v)| u != v).collect();
        for u in 0..re.n as u32 {
            for v in 0..re.n as u32 {
                if csr.has_edge(u, v) != set.contains(&(u, v)) {
                    return Err(format!("membership mismatch at ({u},{v})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn csr_neighbors_sorted_and_degrees_consistent() {
    check("csr sorted", Config::default(), &gen(), |re| {
        let csr = Csr::from_edges(re.n, &re.edges, true);
        let mut total = 0;
        for v in 0..re.n as u32 {
            let nbrs = csr.neighbors(v);
            if !nbrs.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("neighbors of {v} not strictly sorted: {nbrs:?}"));
            }
            // symmetrized: v in N(u) <=> u in N(v)
            for &u in nbrs {
                if !csr.neighbors(u).contains(&v) {
                    return Err(format!("asymmetry: {v} -> {u}"));
                }
            }
            total += nbrs.len();
        }
        if total != csr.m() {
            return Err("degree sum != m".into());
        }
        Ok(())
    });
}

#[test]
fn ordering_roundtrip_and_degree_monotonicity() {
    check("ordering", Config::default(), &gen(), |re| {
        let g = graph_of(re);
        let ord = VertexOrdering::degree_descending(&g);
        for v in 0..re.n as u32 {
            if ord.old_of_new[ord.new_of_old[v as usize] as usize] != v {
                return Err(format!("perm not a bijection at {v}"));
            }
        }
        let h = ord.apply(&g);
        for v in 1..re.n as u32 {
            if h.und_degree(v - 1) < h.und_degree(v) {
                return Err(format!("degrees not descending at {v}"));
            }
        }
        if h.m() != g.m() {
            return Err("edge count changed by relabel".into());
        }
        Ok(())
    });
}

#[test]
fn io_roundtrip_preserves_graph() {
    let cfg = Config { cases: 16, ..Default::default() };
    check("io roundtrip", cfg, &gen(), |re| {
        let g = graph_of(re);
        let path = std::env::temp_dir().join(format!("vdmc_prop_{}_{}.tsv", std::process::id(), re.n));
        io::write_edge_list(&g, &path).map_err(|e| e.to_string())?;
        let h = io::load_edge_list(&path, re.directed).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        // vertex count can shrink when trailing vertices are isolated —
        // compare edges only
        if g.directed {
            let a: Vec<_> = g.out.edges().collect();
            let b: Vec<_> = h.out.edges().collect();
            if a != b {
                return Err("directed edge lists differ after roundtrip".into());
            }
        }
        Ok(())
    });
}

#[test]
fn kcore_peeling_invariant() {
    check("kcore", Config { cases: 24, ..Default::default() }, &gen(), |re| {
        let g = graph_of(re);
        let core = kcore::core_numbers(&g);
        for v in 0..re.n as u32 {
            let k = core[v as usize];
            let strong =
                g.und.neighbors(v).iter().filter(|&&u| core[u as usize] >= k).count() as u32;
            if strong < k {
                return Err(format!("vertex {v}: core {k} but only {strong} strong neighbors"));
            }
        }
        Ok(())
    });
}

#[test]
fn pagerank_is_a_distribution() {
    check("pagerank sum", Config { cases: 16, ..Default::default() }, &gen(), |re| {
        if re.n == 0 {
            return Ok(());
        }
        let g = graph_of(re);
        let r = pagerank::pagerank(&g, 0.85, 1e-12, 300);
        let sum: f64 = r.iter().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("pagerank sums to {sum}"));
        }
        if r.iter().any(|&x| x < 0.0) {
            return Err("negative rank".into());
        }
        Ok(())
    });
}

#[test]
fn distance_distribution_bounded() {
    check("distance rows", Config { cases: 12, ..Default::default() }, &gen(), |re| {
        if re.n < 2 {
            return Ok(());
        }
        let g = graph_of(re);
        let dd = distance::distance_distribution(&g, re.n);
        for (v, row) in dd.iter().enumerate() {
            let s: f64 = row.iter().sum();
            if !(0.0..=1.0 + 1e-9).contains(&s) {
                return Err(format!("row {v} sums to {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn generators_deterministic_and_in_range() {
    for seed in [1u64, 2, 3] {
        let a = generators::barabasi_albert(120, 3, seed);
        let b = generators::barabasi_albert(120, 3, seed);
        assert_eq!(a.und, b.und, "BA not deterministic for seed {seed}");
        let c = generators::gnp_directed(80, 0.1, seed);
        for (u, v) in c.out.edges() {
            assert!(u != v && (u as usize) < 80 && (v as usize) < 80);
        }
    }
}
