//! Property tests over the enumeration core, using the in-repo shrinking
//! harness (util::prop — proptest is not in the offline vendor set).
//!
//! The central invariants of the paper's Section 5 proof:
//!   P1  every connected k-subset is counted once and only once;
//!   P2  per-vertex counts sum to k x instance count;
//!   P3  the parallel coordinator equals the serial baseline for every
//!       worker count / counter mode / ordering;
//!   P4  undirected counts are invariant under vertex relabeling;
//!   P5  erasing edge directions preserves instance totals and per-vertex
//!       participation.

use vdmc::baselines;
use vdmc::coordinator::{count_motifs, CountConfig};
use vdmc::graph::csr::Graph;
use vdmc::motifs::counter::CounterMode;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::util::prop::{check, Config, EdgeListGen, RandomEdges};
use vdmc::util::rng::Pcg32;

fn graph_of(re: &RandomEdges) -> Graph {
    Graph::from_edges(re.n, &re.edges, re.directed)
}

fn directed_gen() -> EdgeListGen {
    EdgeListGen { n_lo: 4, n_hi: 16, p: 0.25, directed: true }
}

fn cases() -> Config {
    Config { cases: 40, ..Default::default() }
}

#[test]
fn p1_p3_vdmc_equals_naive_ground_truth() {
    check("vdmc == naive", cases(), &directed_gen(), |re| {
        let g = graph_of(re);
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in [Direction::Directed, Direction::Undirected] {
                let brute = baselines::naive::count(&g, size, dir);
                let fast = count_motifs(
                    &g,
                    &CountConfig { size, direction: dir, workers: 3, ..Default::default() },
                )
                .map_err(|e| e.to_string())?;
                if brute.per_vertex != fast.per_vertex {
                    return Err(format!(
                        "{size:?} {dir:?}: naive {:?} != vdmc {:?}",
                        brute.class_instances(),
                        fast.class_instances()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn p2_sum_rule() {
    check("sum rule", cases(), &directed_gen(), |re| {
        let g = graph_of(re);
        for (size, k) in [(MotifSize::Three, 3u64), (MotifSize::Four, 4u64)] {
            let c = count_motifs(
                &g,
                &CountConfig { size, direction: Direction::Directed, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let total: u64 = c.per_vertex.iter().sum();
            if total != k * c.total_instances {
                return Err(format!("sum {total} != {k} * {}", c.total_instances));
            }
        }
        Ok(())
    });
}

#[test]
fn p3_counter_modes_and_workers_agree() {
    check("modes agree", cases(), &directed_gen(), |re| {
        let g = graph_of(re);
        let mk = |workers, counter, reorder| CountConfig {
            size: MotifSize::Four,
            direction: Direction::Directed,
            workers,
            counter,
            reorder,
            ..Default::default()
        };
        let reference = count_motifs(&g, &mk(1, CounterMode::Sharded, true)).map_err(|e| e.to_string())?;
        for workers in [2usize, 5] {
            for counter in [CounterMode::Atomic, CounterMode::Sharded] {
                for reorder in [true, false] {
                    let c = count_motifs(&g, &mk(workers, counter, reorder)).map_err(|e| e.to_string())?;
                    if c.per_vertex != reference.per_vertex {
                        return Err(format!("mismatch at workers={workers} {counter:?} reorder={reorder}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn p4_relabeling_invariance() {
    check("relabel invariance", cases(), &directed_gen(), |re| {
        let g = graph_of(re);
        let cfg = CountConfig {
            size: MotifSize::Four,
            direction: Direction::Undirected,
            ..Default::default()
        };
        let base = count_motifs(&g, &cfg).map_err(|e| e.to_string())?;

        // random permutation of vertex ids
        let mut rng = Pcg32::seeded(re.edges.len() as u64 + re.n as u64);
        let mut perm: Vec<u32> = (0..re.n as u32).collect();
        rng.shuffle(&mut perm);
        let edges: Vec<(u32, u32)> = re
            .edges
            .iter()
            .map(|&(u, v)| (perm[u as usize], perm[v as usize]))
            .collect();
        let h = Graph::from_edges(re.n, &edges, re.directed);
        let relabeled = count_motifs(&h, &cfg).map_err(|e| e.to_string())?;

        if base.total_instances != relabeled.total_instances {
            return Err(format!(
                "instances changed under relabeling: {} -> {}",
                base.total_instances, relabeled.total_instances
            ));
        }
        for v in 0..re.n as u32 {
            if base.vertex(v) != relabeled.vertex(perm[v as usize]) {
                return Err(format!("vertex {v} counts changed under relabeling"));
            }
        }
        Ok(())
    });
}

#[test]
fn p5_direction_erasure_consistency() {
    check("direction erasure", cases(), &directed_gen(), |re| {
        let g = graph_of(re);
        for size in [MotifSize::Three, MotifSize::Four] {
            let directed = count_motifs(
                &g,
                &CountConfig { size, direction: Direction::Directed, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            let undirected = count_motifs(
                &g,
                &CountConfig { size, direction: Direction::Undirected, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            // same vertex subsets are enumerated either way
            if directed.total_instances != undirected.total_instances {
                return Err(format!(
                    "{size:?}: directed {} vs undirected {} instances",
                    directed.total_instances, undirected.total_instances
                ));
            }
            for v in 0..g.n() as u32 {
                let d: u64 = directed.vertex(v).iter().sum();
                let u: u64 = undirected.vertex(v).iter().sum();
                if d != u {
                    return Err(format!("vertex {v}: directed {d} vs undirected {u}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn slow_baseline_matches_on_random_graphs() {
    let gen = EdgeListGen { n_lo: 5, n_hi: 14, p: 0.3, directed: true };
    check("slow == vdmc", Config { cases: 20, ..Default::default() }, &gen, |re| {
        let g = graph_of(re);
        for size in [MotifSize::Three, MotifSize::Four] {
            let slow = baselines::slow::count(&g, size, Direction::Directed);
            let fast = count_motifs(
                &g,
                &CountConfig { size, direction: Direction::Directed, ..Default::default() },
            )
            .map_err(|e| e.to_string())?;
            if slow.per_vertex != fast.per_vertex {
                return Err(format!("{size:?}: slow baseline diverges"));
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_tiny_graphs() {
    for n in 0..4usize {
        let g = Graph::from_edges(n, &[], true);
        for size in [MotifSize::Three, MotifSize::Four] {
            let c = count_motifs(
                &g,
                &CountConfig { size, direction: Direction::Directed, ..Default::default() },
            )
            .unwrap();
            assert_eq!(c.total_instances, 0, "n={n} {size:?}");
            assert!(c.per_vertex.iter().all(|&x| x == 0));
        }
    }
}
