//! Cross-module integration: generators -> ordering -> coordinator ->
//! theory/closed forms, on workloads big enough to exercise the work
//! queue and small enough for CI.

use vdmc::baselines;
use vdmc::coordinator::{count_motifs, count_motifs_with_report, CountConfig};
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::theory;
use vdmc::theory::closed_form;

#[test]
fn scale_free_graph_full_pipeline() {
    // BA graphs are the paper's real-world stand-in: heavy hubs stress the
    // (root, neighbor) splitting
    let g = generators::barabasi_albert(500, 4, 77);
    for (size, k) in [(MotifSize::Three, 3u64), (MotifSize::Four, 4u64)] {
        let (c, report) = count_motifs_with_report(
            &g,
            &CountConfig { size, direction: Direction::Undirected, workers: 4, ..Default::default() },
        )
        .unwrap();
        assert!(c.total_instances > 0);
        assert_eq!(c.per_vertex.iter().sum::<u64>(), k * c.total_instances);
        assert_eq!(report.queue_units, g.und.m() / 2);
        // the hub participates in the most motifs
        let hub = (0..g.n() as u32).max_by_key(|&v| g.und_degree(v)).unwrap();
        let hub_total: u64 = c.vertex(hub).iter().sum();
        let median_v = g.n() as u32 / 2;
        let median_total: u64 = c.vertex(median_v).iter().sum();
        assert!(hub_total > median_total, "hub {hub_total} <= median {median_total}");
    }
}

#[test]
fn directed_triad_census_against_naive_medium() {
    // a denser directed graph than the property tests use
    let g = generators::gnp_directed(60, 0.15, 3);
    let brute = baselines::naive::count(&g, MotifSize::Three, Direction::Directed);
    let fast = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Three, direction: Direction::Directed, workers: 3, ..Default::default() },
    )
    .unwrap();
    assert_eq!(brute.per_vertex, fast.per_vertex);
    // all 13 directed triad classes appear at this density
    let inst = fast.class_instances();
    let populated = inst.iter().filter(|&&x| x > 0).count();
    assert!(populated >= 12, "only {populated}/13 triad classes populated");
}

#[test]
fn ring_and_clique_closed_forms_at_scale() {
    let n = 1000u64;
    let g = generators::ring(n as usize);
    let c = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Four, direction: Direction::Undirected, ..Default::default() },
    )
    .unwrap();
    // n consecutive-quadruple motifs, each vertex in 4
    assert_eq!(c.total_instances, n);
    for v in 0..n as u32 {
        assert_eq!(c.vertex(v).iter().sum::<u64>(), closed_form::ring_4paths_per_vertex(n));
    }

    let g = generators::complete(12, false);
    let c = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Four, direction: Direction::Undirected, ..Default::default() },
    )
    .unwrap();
    assert_eq!(c.vertex(0)[c.n_classes - 1], closed_form::clique_k4_per_vertex(12));
}

#[test]
fn gnp_expectation_at_bench_scale() {
    // the Fig 3 fit at the size the bench uses, as a regression gate
    let (n, p) = (600usize, 0.04);
    let g = generators::gnp_directed(n, p, 11);
    let c = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Three, direction: Direction::Directed, ..Default::default() },
    )
    .unwrap();
    let p_hat = theory::realized_p(&g, Direction::Directed);
    let expected = theory::expected_instances(3, Direction::Directed, n, p_hat);
    let observed: Vec<f64> = c.class_instances().iter().map(|&x| x as f64).collect();
    for (o, e) in observed.iter().zip(&expected) {
        if *e > 2000.0 {
            assert!((o - e).abs() / e < 0.10, "obs {o} exp {e}");
        }
    }
}

#[test]
fn stream_batches_respect_contract() {
    use vdmc::coordinator::stream_instances;
    let g = generators::gnp_directed(50, 0.1, 5);
    let batch = 256usize;
    let mut total_valid = 0u64;
    let mut saw_padding_only_at_tail = true;
    let mut last_batch_padding = false;
    stream_instances(&g, MotifSize::Four, Direction::Directed, true, batch, |verts, slots| {
        assert_eq!(verts.len(), batch * 4);
        assert_eq!(slots.len(), batch);
        if last_batch_padding {
            saw_padding_only_at_tail = false; // a batch followed a padded one
        }
        let mut in_padding = false;
        for (i, &s) in slots.iter().enumerate() {
            if s < 0 {
                in_padding = true;
                // padded rows have sentinel vertices
                for t in 0..4 {
                    assert_eq!(verts[i * 4 + t], -1);
                }
            } else {
                assert!(!in_padding, "valid instance after padding within a batch");
                total_valid += 1;
                let raw = s as usize;
                assert!(raw < 4096);
                for t in 0..4 {
                    let v = verts[i * 4 + t];
                    assert!(v >= 0 && (v as usize) < g.n());
                }
            }
        }
        last_batch_padding = in_padding;
    })
    .unwrap();
    assert!(saw_padding_only_at_tail);
    let reference = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Four, direction: Direction::Directed, ..Default::default() },
    )
    .unwrap();
    assert_eq!(total_valid, reference.total_instances);
}

#[test]
fn matrix_baseline_agrees_at_scale() {
    let g = generators::barabasi_albert(300, 5, 9);
    let dense = baselines::matrix::dense_count3(&g);
    let c = count_motifs(
        &g,
        &CountConfig { size: MotifSize::Three, direction: Direction::Undirected, ..Default::default() },
    )
    .unwrap();
    for v in 0..g.n() {
        assert_eq!(dense[v][0] as u64, c.vertex(v as u32)[0]);
        assert_eq!(dense[v][1] as u64, c.vertex(v as u32)[1]);
    }
}
