//! Property tests for the EnumSink emission pipeline: the Instances
//! output against an independent brute-force oracle, the Sample output's
//! determinism and statistical behavior, and the Scope semantics
//! ("scoped counts equal full-count rows restricted to the scope").
//!
//! The oracle enumerates every C(n, k) vertex subset, keeps the connected
//! ones (undirected view), and classifies them through
//! `encode_adjacency` + `SlotMapper` — it shares no code with the
//! proper-BFS enumerators or the sink layer.

use vdmc::engine::{
    MotifQuery, Output, QueryOutput, SchedulerMode, Scope, Session, SessionConfig,
};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::counter::SlotMapper;
use vdmc::motifs::{encode_adjacency, Direction, MotifSize};

/// (sorted verts, class slot) of every connected induced k-subset.
fn oracle(g: &Graph, size: MotifSize, dir: Direction) -> Vec<(Vec<u32>, u16)> {
    let k = size.k();
    let mapper = SlotMapper::new(k, dir);
    let mut out: Vec<(Vec<u32>, u16)> = Vec::new();
    let mut consider = |vs: &[u32]| {
        let m = vs.len();
        let mut adj = vec![false; m * m];
        for i in 0..m {
            for j in 0..m {
                if i != j {
                    adj[i * m + j] = g.und.has_edge(vs[i], vs[j]);
                }
            }
        }
        let mut seen = vec![false; m];
        seen[0] = true;
        let mut stack = vec![0usize];
        let mut cnt = 1;
        while let Some(x) = stack.pop() {
            for y in 0..m {
                if !seen[y] && adj[x * m + y] {
                    seen[y] = true;
                    cnt += 1;
                    stack.push(y);
                }
            }
        }
        if cnt < m {
            return;
        }
        let raw = match dir {
            Direction::Directed => encode_adjacency(k, |i, j| g.out.has_edge(vs[i], vs[j])),
            Direction::Undirected => encode_adjacency(k, |i, j| g.und.has_edge(vs[i], vs[j])),
        };
        out.push((vs.to_vec(), mapper.slot(raw)));
    };
    let n = g.n() as u32;
    match size {
        MotifSize::Three => {
            for a in 0..n {
                for b in (a + 1)..n {
                    for c in (b + 1)..n {
                        consider(&[a, b, c]);
                    }
                }
            }
        }
        MotifSize::Four => {
            for a in 0..n {
                for b in (a + 1)..n {
                    for c in (b + 1)..n {
                        for d in (c + 1)..n {
                            consider(&[a, b, c, d]);
                        }
                    }
                }
            }
        }
    }
    out.sort();
    out
}

/// Run an untruncated Instances query and return (sorted verts, slot).
fn engine_instances(
    session: &Session,
    size: MotifSize,
    dir: Direction,
    scope: Scope,
) -> Vec<(Vec<u32>, u16)> {
    let q = MotifQuery {
        size,
        direction: dir,
        output: Output::Instances { limit: usize::MAX >> 1 },
        scope,
        ..Default::default()
    };
    let list = match session.query(&q).unwrap() {
        QueryOutput::Instances(l) => l,
        other => panic!("{other:?}"),
    };
    assert!(!list.truncated, "untruncated run must keep everything");
    list.instances.into_iter().map(|i| (i.verts, i.class_slot)).collect()
}

fn test_graphs() -> Vec<(&'static str, Graph)> {
    vec![
        ("gnp-directed-1", generators::gnp_directed(16, 0.25, 1)),
        ("gnp-directed-2", generators::gnp_directed(16, 0.2, 2)),
        ("gnp-undirected", generators::gnp_undirected(18, 0.22, 7)),
        ("star", generators::star(12)),
        ("ba", generators::barabasi_albert(20, 3, 5)),
    ]
}

fn directions(g: &Graph) -> Vec<Direction> {
    if g.directed {
        vec![Direction::Directed, Direction::Undirected]
    } else {
        vec![Direction::Undirected]
    }
}

// ------------------------------------------------------- (a) instances

#[test]
fn instances_are_set_equal_to_the_oracle() {
    for (name, g) in test_graphs() {
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in directions(&g) {
                let want = oracle(&g, size, dir);
                let got = engine_instances(&session, size, dir, Scope::All);
                assert_eq!(got, want, "{name} {size:?} {dir:?}");
            }
        }
    }
}

#[test]
fn scoped_instances_are_exactly_the_scope_touching_oracle_subset() {
    for (name, g) in [
        ("gnp-directed", generators::gnp_directed(16, 0.25, 3)),
        ("ba", generators::barabasi_albert(20, 3, 9)),
    ] {
        let session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
        let scope_vs: Vec<u32> = vec![0, 5, 11];
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in directions(&g) {
                let want: Vec<(Vec<u32>, u16)> = oracle(&g, size, dir)
                    .into_iter()
                    .filter(|(vs, _)| vs.iter().any(|v| scope_vs.contains(v)))
                    .collect();
                let got =
                    engine_instances(&session, size, dir, Scope::Vertices(scope_vs.clone()));
                assert_eq!(got, want, "{name} {size:?} {dir:?} scoped");
            }
        }
    }
}

// ---------------------------------------------------------- (b) sample

#[test]
fn sample_is_deterministic_across_schedulers_and_worker_counts() {
    let g = generators::barabasi_albert(150, 3, 4);
    let runs: Vec<Vec<(u64, Vec<(Vec<u32>, u16)>)>> = [
        (1usize, SchedulerMode::SharedCursor),
        (4, SchedulerMode::SharedCursor),
        (4, SchedulerMode::WorkStealing),
        (4, SchedulerMode::WorkStealingBatch),
        (7, SchedulerMode::WorkStealingBatch),
    ]
    .into_iter()
    .map(|(workers, scheduler)| {
        let session = Session::load_with(&g, &SessionConfig { workers, ..Default::default() });
        let q = MotifQuery {
            size: MotifSize::Three,
            direction: Direction::Undirected,
            scheduler,
            output: Output::Sample { per_class: 9, seed: 77 },
            ..Default::default()
        };
        match session.query(&q).unwrap() {
            QueryOutput::Sample(s) => s
                .classes
                .into_iter()
                .map(|c| {
                    (
                        c.seen,
                        c.instances.into_iter().map(|i| (i.verts, i.class_slot)).collect(),
                    )
                })
                .collect(),
            other => panic!("{other:?}"),
        }
    })
    .collect();
    for run in &runs[1..] {
        assert_eq!(run, &runs[0], "fixed seed must pin the sample exactly");
    }
}

#[test]
fn sample_reservoirs_are_subsets_with_exact_seen_counts() {
    let g = generators::gnp_directed(16, 0.3, 11);
    let session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
    for size in [MotifSize::Three, MotifSize::Four] {
        for dir in directions(&g) {
            let want = oracle(&g, size, dir);
            let q = MotifQuery {
                size,
                direction: dir,
                output: Output::Sample { per_class: 5, seed: 13 },
                ..Default::default()
            };
            let s = match session.query(&q).unwrap() {
                QueryOutput::Sample(s) => s,
                other => panic!("{other:?}"),
            };
            assert_eq!(s.total_seen, want.len() as u64, "{size:?} {dir:?}");
            for c in &s.classes {
                let class_want: Vec<&(Vec<u32>, u16)> =
                    want.iter().filter(|(_, slot)| *slot == c.slot).collect();
                assert_eq!(c.seen, class_want.len() as u64, "exact per-class seen");
                assert_eq!(c.instances.len() as u64, c.seen.min(5));
                for inst in &c.instances {
                    assert!(
                        class_want.iter().any(|(vs, _)| *vs == inst.verts),
                        "sampled instance {:?} not in the oracle set",
                        inst.verts
                    );
                }
                // no duplicates inside a reservoir
                for (i, a) in c.instances.iter().enumerate() {
                    for b in &c.instances[i + 1..] {
                        assert_ne!(a.verts, b.verts, "duplicate in reservoir");
                    }
                }
            }
        }
    }
}

#[test]
fn sample_estimates_per_vertex_participation_within_bounds() {
    // The reservoir is a uniform without-replacement draw: for any vertex
    // v and class c, occurrences(v in sample) / |sample| estimates
    // count[v][c] / seen_c. Everything is deterministic for the fixed
    // seed, so the 5σ-wide bound below either always holds or never does.
    let g = generators::barabasi_albert(300, 3, 21);
    let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
    let counts = session
        .count(&MotifQuery { direction: Direction::Undirected, ..Default::default() })
        .unwrap();
    let per_class = 60usize;
    let q = MotifQuery {
        direction: Direction::Undirected,
        output: Output::Sample { per_class, seed: 20_22 },
        ..Default::default()
    };
    let s = match session.query(&q).unwrap() {
        QueryOutput::Sample(s) => s,
        other => panic!("{other:?}"),
    };
    // the busiest vertex overall
    let hub = (0..g.n() as u32)
        .max_by_key(|&v| counts.vertex(v).iter().sum::<u64>())
        .unwrap();
    let mut checked = 0;
    for c in &s.classes {
        if c.seen < 200 {
            continue; // too small for a statistical statement
        }
        let kept = c.instances.len() as f64;
        let occurrences =
            c.instances.iter().filter(|i| i.verts.contains(&hub)).count() as f64;
        let p_true = counts.vertex(hub)[c.slot as usize] as f64 / c.seen as f64;
        let p_est = occurrences / kept;
        // binomial-ish 5σ + slack: wide enough to be robust, tight
        // enough to catch a broken (non-uniform) selection
        let sigma = (p_true * (1.0 - p_true) / kept).sqrt();
        assert!(
            (p_est - p_true).abs() <= 5.0 * sigma + 0.05,
            "class m{}: estimated {p_est:.3} vs true {p_true:.3} (σ={sigma:.3})",
            c.class_id
        );
        // ... and the class-total estimate k/seen·total is exact by
        // construction: seen IS the class total
        assert_eq!(c.seen, counts.class_instances()[c.slot as usize]);
        checked += 1;
    }
    assert!(checked > 0, "at least one class must be large enough to check");
}

// -------------------------------------------------------- (c) scoping

#[test]
fn scoped_counts_equal_full_rows_restricted_to_the_scope() {
    for (name, g) in test_graphs() {
        let session = Session::load_with(&g, &SessionConfig { workers: 3, ..Default::default() });
        let scope_vs: Vec<u32> = vec![0, 3, 9];
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in directions(&g) {
                let full = session
                    .count(&MotifQuery { size, direction: dir, ..Default::default() })
                    .unwrap();
                let scoped = session
                    .count(&MotifQuery {
                        size,
                        direction: dir,
                        scope: Scope::Vertices(scope_vs.clone()),
                        ..Default::default()
                    })
                    .unwrap();
                for v in 0..g.n() as u32 {
                    if scope_vs.contains(&v) {
                        assert_eq!(
                            scoped.vertex(v),
                            full.vertex(v),
                            "{name} {size:?} {dir:?} v{v}"
                        );
                    } else {
                        assert!(
                            scoped.vertex(v).iter().all(|&c| c == 0),
                            "{name} {size:?} {dir:?} v{v} must be zeroed"
                        );
                    }
                }
                // total = oracle instances touching the scope, exactly
                let want_total = oracle(&g, size, dir)
                    .iter()
                    .filter(|(vs, _)| vs.iter().any(|v| scope_vs.contains(v)))
                    .count() as u64;
                assert_eq!(scoped.total_instances, want_total, "{name} {size:?} {dir:?}");
            }
        }
    }
}

#[test]
fn neighborhood_scope_rows_match_full_rows_across_scheduler_modes() {
    let g = generators::barabasi_albert(120, 3, 13);
    let session = Session::load_with(&g, &SessionConfig { workers: 4, ..Default::default() });
    let full = session
        .count(&MotifQuery {
            size: MotifSize::Four,
            direction: Direction::Undirected,
            ..Default::default()
        })
        .unwrap();
    let ball = session.neighborhood(&[2, 50], 1).unwrap();
    for scheduler in [
        SchedulerMode::SharedCursor,
        SchedulerMode::WorkStealing,
        SchedulerMode::WorkStealingBatch,
    ] {
        let scoped = session
            .count(&MotifQuery {
                size: MotifSize::Four,
                direction: Direction::Undirected,
                scheduler,
                scope: Scope::Neighborhood { seeds: vec![2, 50], radius: 1 },
                ..Default::default()
            })
            .unwrap();
        for &v in &ball {
            assert_eq!(scoped.vertex(v), full.vertex(v), "{scheduler:?} v{v}");
        }
        for v in 0..g.n() as u32 {
            if !ball.contains(&v) {
                assert!(scoped.vertex(v).iter().all(|&c| c == 0), "{scheduler:?} v{v}");
            }
        }
    }
}

#[test]
fn scoped_queries_over_dirty_overlay_match_reload() {
    use vdmc::stream::EdgeDelta;
    let g = generators::gnp_directed(40, 0.12, 17);
    let mut session = Session::load_with(
        &g,
        &SessionConfig { workers: 2, compact_ratio: f64::INFINITY, ..Default::default() },
    );
    let deltas: Vec<EdgeDelta> =
        (0..12u32).map(|i| EdgeDelta::insert(i, (i * 13 + 5) % 40)).collect();
    session.apply_edges(&deltas).unwrap();
    assert!(session.overlay_entries() > 0, "overlay must be dirty");

    let snapshot = session.snapshot_graph();
    let scope = Scope::Neighborhood { seeds: vec![3], radius: 1 };
    for size in [MotifSize::Three, MotifSize::Four] {
        let q = MotifQuery {
            size,
            direction: Direction::Directed,
            scope: scope.clone(),
            ..Default::default()
        };
        let dirty = session.count(&q).unwrap();
        let fresh = Session::load(&snapshot).count(&q).unwrap();
        assert_eq!(dirty.per_vertex, fresh.per_vertex, "{size:?}");
        assert_eq!(dirty.total_instances, fresh.total_instances);
        // the scope-touching instances also match the snapshot's oracle
        let members = Session::load(&snapshot).neighborhood(&[3], 1).unwrap();
        let want_total = oracle(&snapshot, size, Direction::Directed)
            .iter()
            .filter(|(vs, _)| vs.iter().any(|v| members.contains(v)))
            .count() as u64;
        assert_eq!(dirty.total_instances, want_total, "{size:?}");
    }
}

// --------------------------------------------- maintenance stays Count-only

#[test]
fn delta_maintenance_rejects_non_count_outputs_with_typed_error() {
    use vdmc::stream::CountOnlyError;
    let g = generators::gnp_directed(25, 0.15, 5);
    let mut session = Session::load(&g);
    let err = session
        .maintain_query(&MotifQuery {
            output: Output::Instances { limit: 100 },
            ..Default::default()
        })
        .unwrap_err();
    let typed = err.downcast_ref::<CountOnlyError>().expect("typed CountOnlyError");
    assert!(typed.requested.contains("instances"), "{typed:?}");
    assert!(err.to_string().contains("Count-only"));
}
