//! PJRT runtime integration tests — require `make artifacts` to have run
//! (skipped with a message otherwise).
//!
//! These are the tests that prove the three layers compose: the Python
//! AOT path produced HLO the Rust PJRT client can execute, with numerics
//! matching the in-Rust implementations bit-for-bit (integer counts) or to
//! f32 tolerance (theory).

use vdmc::coordinator::{count_motifs, stream_instances, CountConfig};
use vdmc::graph::generators;
use vdmc::motifs::counter::SlotMapper;
use vdmc::motifs::iso::{iso_table, NO_SLOT};
use vdmc::motifs::{Direction, MotifSize};
use vdmc::runtime::artifacts::{load_iso_table, ArtifactManifest};
use vdmc::runtime::exec::{padded_classes, ArtifactRunner, CountAggregator, TensorData, BATCH, N_VERT_BLOCK};
use vdmc::theory;

fn runner() -> Option<ArtifactRunner> {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactRunner::new(&dir).expect("runner"))
}

#[test]
fn iso_tables_cross_check_python_vs_rust() {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("iso3.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for k in [3usize, 4] {
        let rows = load_iso_table(&dir, k).expect("load iso table");
        let table = iso_table(k);
        assert_eq!(rows.len(), table.canon.len());
        for row in rows {
            let id = row.raw_id as usize;
            assert_eq!(row.canonical_id, table.canon[id], "k={k} id={id} canon");
            assert_eq!(row.connected, table.connected[id], "k={k} id={id} conn");
            let rust_slot =
                if table.class_slot[id] == NO_SLOT { -1 } else { table.class_slot[id] as i32 };
            assert_eq!(row.class_slot, rust_slot, "k={k} id={id} slot");
        }
    }
}

#[test]
fn aggregate_artifact_matches_rust_tables() {
    let Some(r) = runner() else { return };
    for k in [3usize, 4] {
        let n_ids = 1usize << (k * (k - 1));
        let c_pad = padded_classes(k);
        let table = iso_table(k);
        // histogram: row v has count v+1 at raw id (v * 7) % n_ids
        let mut hist = vec![0f32; N_VERT_BLOCK * n_ids];
        for v in 0..N_VERT_BLOCK {
            hist[v * n_ids + (v * 7) % n_ids] = (v + 1) as f32;
        }
        let out = r.aggregate(k, &hist).expect("aggregate");
        assert_eq!(out.len(), N_VERT_BLOCK * c_pad);
        for v in 0..N_VERT_BLOCK {
            let raw = (v * 7) % n_ids;
            let slot = table.class_slot[raw];
            for s in 0..c_pad {
                let expect = if slot != NO_SLOT && s == slot as usize { (v + 1) as f32 } else { 0.0 };
                assert_eq!(out[v * c_pad + s], expect, "k={k} v={v} s={s}");
            }
        }
    }
}

#[test]
fn theory_artifact_matches_rust_eq74() {
    let Some(r) = runner() else { return };
    for k in [3usize, 4] {
        let (n, p) = (300usize, 0.07f64);
        let (dir_row, und_row) = r.theory(k, n as f32, p as f32).expect("theory");
        let rust_dir = theory::expected_per_vertex(k, Direction::Directed, n, p);
        for (s, e) in rust_dir.iter().enumerate() {
            let got = dir_row[s] as f64;
            let tol = e.max(1e-3) * 5e-3 + 1e-4;
            assert!((got - e).abs() < tol, "k={k} directed slot {s}: pjrt {got} rust {e}");
        }
        // undirected expectations live at the full-table slots of symmetric classes
        let table = iso_table(k);
        let rust_und = theory::expected_per_vertex(k, Direction::Undirected, n, p);
        for (compact, &full_slot) in table.undirected_slots().iter().enumerate() {
            let got = und_row[full_slot as usize] as f64;
            let e = rust_und[compact];
            let tol = e.max(1e-3) * 5e-3 + 1e-4;
            assert!((got - e).abs() < tol, "k={k} undirected slot {compact}: pjrt {got} rust {e}");
        }
    }
}

#[test]
fn pipeline_artifact_reproduces_enumeration_counts() {
    let Some(r) = runner() else { return };
    // graph small enough that (a) counts are exact in f32 and (b) the
    // interpret-mode pipeline stays fast on one core
    let g = generators::gnp_directed(180, 0.035, 77);
    for (size, k) in [(MotifSize::Three, 3usize), (MotifSize::Four, 4usize)] {
        let direction = Direction::Directed;
        let rust_counts = count_motifs(
            &g,
            &CountConfig { size, direction, workers: 1, ..Default::default() },
        )
        .unwrap();

        let mut agg = CountAggregator::new(&r, k, g.n());
        stream_instances(&g, size, direction, true, BATCH, |verts, slots| {
            agg.push_batch(verts, slots).expect("push");
        })
        .unwrap();
        let pjrt = agg.finish();

        // compare: pjrt rows are padded_classes wide; slots use the FULL
        // (directed) table order, same as rust_counts
        let c_pad = padded_classes(k);
        let nc = rust_counts.n_classes;
        for v in 0..g.n() {
            for s in 0..nc {
                assert_eq!(
                    pjrt[v * c_pad + s],
                    rust_counts.per_vertex[v * nc + s],
                    "k={k} vertex {v} slot {s}"
                );
            }
            for s in nc..c_pad {
                assert_eq!(pjrt[v * c_pad + s], 0, "padding column {s} must be empty");
            }
        }
    }
}

#[test]
fn dense3_artifact_matches_matrix_baseline() {
    let Some(r) = runner() else { return };
    let n = 256; // the artifact's baked adjacency size
    let g = generators::gnp_undirected(n, 0.08, 5);
    let mut adj = vec![0f32; n * n];
    for (u, v) in g.und.edges() {
        adj[u as usize * n + v as usize] = 1.0;
    }
    let out = r.dense3(&adj).expect("dense3");
    let rust = vdmc::baselines::matrix::dense_count3(&g);
    for v in 0..n {
        assert_eq!(out[v * 2] as f64, rust[v][0], "paths at {v}");
        assert_eq!(out[v * 2 + 1] as f64, rust[v][1], "triangles at {v}");
    }
}

#[test]
fn run_rejects_bad_inputs() {
    let Some(r) = runner() else { return };
    // wrong input count
    assert!(r.run("aggregate3", &[]).is_err());
    // wrong element count
    let small = vec![0f32; 8];
    assert!(r.run("aggregate3", &[TensorData::F32(&small)]).is_err());
    // wrong dtype
    let ints = vec![0i32; N_VERT_BLOCK * 64];
    assert!(r.run("aggregate3", &[TensorData::I32(&ints)]).is_err());
    // unknown artifact
    assert!(r.run("nope", &[]).is_err());
}

#[test]
fn slot_mapper_matches_python_classes_tsv() {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("classes3.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for k in [3usize, 4] {
        let text = std::fs::read_to_string(dir.join(format!("classes{k}.tsv"))).unwrap();
        let mapper = SlotMapper::new(k, Direction::Directed);
        let mut rows = 0;
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
            let cols: Vec<&str> = line.split('\t').collect();
            let slot: usize = cols[0].parse().unwrap();
            let class = mapper.classes()[slot];
            assert_eq!(class.canonical_id, cols[1].parse::<u16>().unwrap());
            assert_eq!(class.n_iso, cols[2].parse::<u32>().unwrap());
            assert_eq!(class.n_edges, cols[3].parse::<u32>().unwrap());
            assert_eq!(class.symmetric, cols[4] == "1");
            assert_eq!(class.n_iso_sym, cols[5].parse::<u32>().unwrap());
            rows += 1;
        }
        assert_eq!(rows, mapper.n_classes());
    }
}
