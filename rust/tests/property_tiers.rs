//! Probe-parity property tests for the hybrid adjacency tier: over random
//! G(n,p) digraphs and hub-heavy star / power-law (Barabási–Albert)
//! generators, `--adjacency hybrid` and `--adjacency csr` sessions must
//! produce **bit-identical** `MotifCounts` — 3- and 4-motifs, directed and
//! undirected classification — and keep doing so over an `OverlayView`
//! with pending inserts/deletes (the dirty-count path) and across
//! maintained incremental counters. The bitmap rows are a pure probe
//! accelerator; any divergence anywhere is a correctness bug.

use vdmc::engine::{AdjacencyMode, CountQuery, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::counter::MotifCounts;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::stream::EdgeDelta;
use vdmc::util::rng::Pcg32;

/// Sessions over the same graph in both adjacency modes. Thresholds are
/// deliberately aggressive (`Some(2)`: almost every row becomes a hub) or
/// automatic (`None`: ≈ √m, few hubs) so both the bitmap and the CSR
/// fallback paths run.
fn session_pair(g: &Graph, threshold: Option<usize>) -> (Session, Session) {
    let csr = Session::load_with(
        g,
        &SessionConfig { workers: 2, adjacency: AdjacencyMode::Csr, ..Default::default() },
    );
    let hybrid = Session::load_with(
        g,
        &SessionConfig {
            workers: 2,
            adjacency: AdjacencyMode::Hybrid,
            hub_threshold: threshold,
            ..Default::default()
        },
    );
    (csr, hybrid)
}

fn directions(g: &Graph) -> Vec<Direction> {
    if g.directed {
        vec![Direction::Directed, Direction::Undirected]
    } else {
        vec![Direction::Undirected]
    }
}

fn assert_identical(a: &MotifCounts, b: &MotifCounts, ctx: &str) {
    assert_eq!(a.total_instances, b.total_instances, "instances diverge: {ctx}");
    assert_eq!(a.per_vertex, b.per_vertex, "per-vertex rows diverge: {ctx}");
    assert_eq!(a.class_ids, b.class_ids, "class ids diverge: {ctx}");
}

fn check_static_parity(name: &str, g: &Graph, threshold: Option<usize>) {
    let (csr, hybrid) = session_pair(g, threshold);
    for size in [MotifSize::Three, MotifSize::Four] {
        for dir in directions(g) {
            let q = CountQuery { size, direction: dir, ..Default::default() };
            let a = csr.count(&q).unwrap();
            let b = hybrid.count(&q).unwrap();
            assert_identical(&a, &b, &format!("{name} {size:?} {dir:?} t={threshold:?}"));
        }
    }
}

#[test]
fn static_parity_gnp_digraphs() {
    for seed in [1u64, 7, 23] {
        let g = generators::gnp_directed(60, 0.08, seed);
        check_static_parity("gnp", &g, Some(2));
        check_static_parity("gnp", &g, None);
    }
}

#[test]
fn static_parity_star() {
    // one extreme hub: every probe against it hits the bitmap row
    // (star(120) keeps the C(119,3) 4-set volume test-sized)
    let g = generators::star(120);
    check_static_parity("star", &g, Some(8));
    check_static_parity("star", &g, None);
}

#[test]
fn static_parity_power_law() {
    let und = generators::barabasi_albert(200, 3, 5);
    check_static_parity("ba", &und, Some(4));
    check_static_parity("ba", &und, None);
    let dir = generators::barabasi_albert_directed(200, 3, 0.3, 9);
    check_static_parity("ba-directed", &dir, Some(4));
    check_static_parity("ba-directed", &dir, None);
}

/// A delta batch that both inserts fresh edges and deletes existing ones,
/// in original vertex ids.
fn mixed_batch(g: &Graph, seed: u64, ops: usize) -> Vec<EdgeDelta> {
    let n = g.n() as u32;
    let mut rng = Pcg32::seeded(seed);
    let mut batch = Vec::with_capacity(ops);
    for _ in 0..ops {
        let (u, v) = (rng.below(n), rng.below(n));
        if u == v {
            continue;
        }
        let present =
            if g.directed { g.out.has_edge(u, v) } else { g.und.has_edge(u, v) };
        // flip whatever state we see in the base — the session dedups
        // duplicate inserts / missing deletes on its own
        if present {
            batch.push(EdgeDelta::delete(u, v));
        } else {
            batch.push(EdgeDelta::insert(u, v));
        }
    }
    batch
}

#[test]
fn overlay_parity_with_pending_deltas() {
    // compact_ratio = ∞ keeps the overlay dirty, so counts go through
    // OverlayView's patched fast probes over the (stale) base bitmaps
    for &(directed, seed) in &[(true, 11u64), (false, 12u64)] {
        let g = if directed {
            generators::barabasi_albert_directed(150, 3, 0.25, seed)
        } else {
            generators::barabasi_albert(150, 3, seed)
        };
        let mk = |adjacency| {
            Session::load_with(
                &g,
                &SessionConfig {
                    workers: 2,
                    adjacency,
                    hub_threshold: Some(3),
                    compact_ratio: f64::INFINITY,
                    ..Default::default()
                },
            )
        };
        let mut csr = mk(AdjacencyMode::Csr);
        let mut hybrid = mk(AdjacencyMode::Hybrid);
        let batch = mixed_batch(&g, seed ^ 0xBEEF, 60);
        csr.apply_edges(&batch).unwrap();
        hybrid.apply_edges(&batch).unwrap();
        assert!(hybrid.overlay_entries() > 0, "overlay must stay dirty for this test");
        assert_eq!(csr.overlay_entries(), hybrid.overlay_entries());

        // reload oracle: the mutated graph, loaded fresh
        let fresh = Session::load(&csr.snapshot_graph());
        for size in [MotifSize::Three, MotifSize::Four] {
            for dir in directions(&g) {
                let q = CountQuery { size, direction: dir, ..Default::default() };
                let a = csr.count(&q).unwrap();
                let b = hybrid.count(&q).unwrap();
                assert_identical(&a, &b, &format!("overlay {size:?} {dir:?} directed={directed}"));
                let want = fresh.count(&q).unwrap();
                assert_identical(&b, &want, &format!("overlay-vs-reload {size:?} {dir:?}"));
            }
        }
    }
}

/// The galloping row merge is a pure strategy swap: for every (center,
/// after, target-list) shape — hub rows long enough to trigger the
/// dispatch and tail rows that fall back to the two-pointer walk —
/// `bits_against` must agree bit-for-bit with the `bits_against_merge`
/// oracle, hit and miss targets alike, in both directions.
#[test]
fn gallop_merge_parity_on_hub_rows() {
    use vdmc::motifs::probe::{bits_against, bits_against_merge, GALLOP_RATIO};

    let graphs: Vec<(&str, Graph)> = vec![
        ("star", generators::star(3000)),
        ("ba", generators::barabasi_albert(800, 4, 17)),
        ("ba-directed", generators::barabasi_albert_directed(800, 4, 0.3, 19)),
    ];
    let mut galloped = 0usize;
    for (name, g) in &graphs {
        let n = g.n() as u32;
        // centers: the heaviest rows (gallop candidates) plus tails
        // (merge fallback)
        let mut by_deg: Vec<u32> = (0..n).collect();
        by_deg.sort_by_key(|&v| std::cmp::Reverse(g.und.degree(v)));
        let centers: Vec<u32> =
            by_deg.iter().take(4).chain(by_deg.iter().rev().take(4)).copied().collect();
        let mut rng = Pcg32::seeded(0xD1CE ^ n as u64);
        for &center in &centers {
            for after in [0u32, 5, n / 2] {
                for t_count in [1usize, 3, 10, 40] {
                    let span = (n - after - 1).max(1);
                    let mut targets: Vec<u32> = (0..t_count)
                        .map(|_| after + 1 + rng.below(span))
                        .filter(|&t| t != center)
                        .collect();
                    targets.sort_unstable();
                    targets.dedup();
                    if targets.is_empty() {
                        continue;
                    }
                    let row_len = g.und.neighbors_above(center, after).len();
                    if targets.len() * GALLOP_RATIO <= row_len {
                        galloped += 1;
                    }
                    for dir in directions(g) {
                        let mut fast: Vec<(u32, u8)> = Vec::new();
                        bits_against(g, dir, center, after, &targets, |t, b| {
                            fast.push((t, b));
                        });
                        let mut slow: Vec<(u32, u8)> = Vec::new();
                        bits_against_merge(g, dir, center, after, &targets, |t, b| {
                            slow.push((t, b));
                        });
                        assert_eq!(
                            fast, slow,
                            "{name} center {center} after {after} {dir:?} \
                             ({} targets, row {row_len})",
                            targets.len()
                        );
                    }
                }
            }
        }
    }
    assert!(galloped > 0, "no combination exercised the gallop dispatch");
}

#[test]
fn maintained_counters_parity_across_tiers() {
    let g = generators::barabasi_albert_directed(120, 3, 0.2, 31);
    let mk = |adjacency| {
        Session::load_with(
            &g,
            &SessionConfig {
                workers: 2,
                adjacency,
                hub_threshold: Some(3),
                ..Default::default()
            },
        )
    };
    let mut csr = mk(AdjacencyMode::Csr);
    let mut hybrid = mk(AdjacencyMode::Hybrid);
    for s in [&mut csr, &mut hybrid] {
        s.maintain(MotifSize::Three, Direction::Directed).unwrap();
        s.maintain(MotifSize::Four, Direction::Undirected).unwrap();
    }
    for round in 0..3u64 {
        let batch = mixed_batch(&csr.snapshot_graph(), 100 + round, 30);
        let ra = csr.apply_edges(&batch).unwrap();
        let rb = hybrid.apply_edges(&batch).unwrap();
        assert_eq!(ra.inserted, rb.inserted, "round {round}");
        assert_eq!(ra.deleted, rb.deleted, "round {round}");
        assert_eq!(ra.reenumerated_sets, rb.reenumerated_sets, "round {round}");
        for (size, dir) in
            [(MotifSize::Three, Direction::Directed), (MotifSize::Four, Direction::Undirected)]
        {
            let a = csr.maintained_counts(size, dir).unwrap();
            let b = hybrid.maintained_counts(size, dir).unwrap();
            assert_identical(&a, &b, &format!("maintained {size:?} {dir:?} round {round}"));
        }
    }
}
