//! Concurrency stress: the ThreadSanitizer target for the lock-free core.
//!
//! These tests race the same structures the loom models check
//! (`tests/loom_models.rs`), but on real OS threads at real scale, so
//! they double as the `-Zsanitizer=thread` binaries in CI's `tsan` job:
//!
//! ```text
//! RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
//!     --target x86_64-unknown-linux-gnu -p vdmc --release \
//!     --test concurrency_stress
//! ```
//!
//! Under a plain `cargo test` they run as fast bounded stress (tier-1
//! keeps them cheap); under TSan every interleaving that *does* happen
//! is checked for data races at the hardware level — complementing
//! loom's exhaustive-but-small state spaces with big-but-sampled ones.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread;

use vdmc::engine::cancel::{AbortReason, CancelToken};
use vdmc::engine::deque::{CursorQueue, StealDeques};
use vdmc::engine::snapshot::{Snapshot, SnapshotCell};
use vdmc::service::admission::AdmissionGate;
use vdmc::telemetry::metrics::MetricsRegistry;

/// Same minimal snapshot as the loom models: epoch stamp + fixed size.
struct TestSnap {
    epoch: u64,
    bytes: usize,
}

impl TestSnap {
    fn new(epoch: u64) -> Arc<TestSnap> {
        Arc::new(TestSnap { epoch, bytes: 100 })
    }
}

impl Snapshot for TestSnap {
    fn epoch(&self) -> u64 {
        self.epoch
    }
    fn memory_bytes(&self) -> usize {
        self.bytes
    }
    fn retained_vs(&self, head: &TestSnap) -> usize {
        if self.epoch == head.epoch {
            0
        } else {
            self.bytes
        }
    }
}

#[test]
fn snapshot_readers_race_a_committing_writer() {
    const COMMITS: u64 = 50;
    const READS: usize = 200;
    let cell = Arc::new(SnapshotCell::new(TestSnap::new(0)));
    thread::scope(|scope| {
        for _ in 0..3 {
            scope.spawn(|| {
                let mut last = 0u64;
                for _ in 0..READS {
                    let pin = cell.head();
                    let epoch = pin.epoch();
                    assert!(epoch >= last, "epoch went backwards: {last} -> {epoch}");
                    assert!(epoch <= COMMITS, "epoch from the future: {epoch}");
                    // accounting must never undercount a live pin
                    assert!(cell.pinned_snapshots() >= 1);
                    last = epoch;
                }
            });
        }
        scope.spawn(|| {
            // the single writer (the production role of the per-graph
            // writer mutex holder) stacks epochs 1..=COMMITS
            for e in 1..=COMMITS {
                cell.commit(TestSnap::new(e));
            }
        });
    });
    assert_eq!(cell.epoch(), COMMITS);
    assert_eq!(cell.pinned_snapshots(), 0, "all pins dropped with the threads");
    assert_eq!(cell.retained_bytes(), 0);
    assert_eq!(cell.resident_bytes(), 100);
}

#[test]
fn cancel_children_spawned_during_cancel_all_observe_it() {
    for _ in 0..50 {
        let conn = CancelToken::new();
        let children = thread::scope(|scope| {
            let canceller = {
                let conn = conn.clone();
                scope.spawn(move || {
                    thread::yield_now();
                    conn.cancel(AbortReason::ClientGone);
                })
            };
            // spawn children while the cancel is (maybe) in flight —
            // the serve loop's cancel-vs-spawn race at stress scale
            let mut children = Vec::new();
            for i in 0..100 {
                let child = conn.child(None, Some(format!("req-{i}")));
                match child.check() {
                    None | Some(AbortReason::ClientGone) => {}
                    other => panic!("impossible mid-race reason: {other:?}"),
                }
                children.push(child);
            }
            canceller.join().unwrap();
            children
        });
        for (i, child) in children.iter().enumerate() {
            assert_eq!(
                child.check(),
                Some(AbortReason::ClientGone),
                "child {i} lost its parent's cancel"
            );
        }
        assert_eq!(conn.child(None, None).check(), Some(AbortReason::ClientGone));
    }
}

#[test]
fn racing_cancels_elect_exactly_one_winner() {
    const REASONS: [AbortReason; 4] = [
        AbortReason::Deadline,
        AbortReason::ClientGone,
        AbortReason::Shutdown,
        AbortReason::Shed,
    ];
    for _ in 0..200 {
        let token = CancelToken::new();
        let winners: Vec<AbortReason> = thread::scope(|scope| {
            let handles: Vec<_> = REASONS
                .iter()
                .map(|&reason| {
                    let token = token.clone();
                    scope.spawn(move || token.cancel(reason).then_some(reason))
                })
                .collect();
            handles.into_iter().filter_map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(winners.len(), 1, "exactly one cancel must win: {winners:?}");
        assert_eq!(token.check(), Some(winners[0]), "observed reason must be the winner's");
    }
}

#[test]
fn admission_gate_balances_under_stress_and_unwinds() {
    const THREADS: usize = 8;
    const ROUNDS: usize = 500;
    let gate = Arc::new(AdmissionGate::new());
    thread::scope(|scope| {
        for t in 0..THREADS {
            let gate = Arc::clone(&gate);
            scope.spawn(move || {
                for round in 0..ROUNDS {
                    if (round + t) % 7 == 0 {
                        // permit dropped by unwinding instead of return
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            let (inflight, _permit) = gate.enter();
                            assert!((1..=THREADS).contains(&inflight));
                            panic!("request died mid-enumeration");
                        }));
                        assert!(result.is_err());
                    } else {
                        let (inflight, permit) = gate.enter();
                        assert!((1..=THREADS).contains(&inflight), "inflight {inflight}");
                        drop(permit);
                    }
                }
            });
        }
    });
    assert_eq!(gate.inflight(), 0, "every slot must be returned exactly once");
}

#[test]
fn histogram_stays_exact_under_racing_recorders_and_scrapes() {
    const THREADS: u64 = 4;
    const RECORDS: u64 = 1000;
    let registry = Arc::new(MetricsRegistry::new());
    let hist = registry.histogram("stress_seconds", "stress test histogram");
    thread::scope(|scope| {
        for t in 0..THREADS {
            let hist = Arc::clone(&hist);
            scope.spawn(move || {
                for i in 0..RECORDS {
                    // spread samples over several buckets deterministically
                    hist.record(1e-6 * ((t * RECORDS + i) % 64 + 1) as f64);
                }
            });
        }
        // concurrent scraper: snapshots must be internally consistent
        // (count rebuilt from bucket reads) and monotone over time
        let hist = Arc::clone(&hist);
        scope.spawn(move || {
            let mut last = 0u64;
            for _ in 0..100 {
                let snap = hist.snapshot();
                assert!(snap.count >= last, "snapshot count regressed");
                assert!(snap.count <= THREADS * RECORDS, "snapshot invented samples");
                if snap.count > 0 {
                    let (p50, p100) = (snap.quantile(0.5), snap.quantile(1.0));
                    assert!(p50 <= p100, "quantiles must be ordered: {p50} > {p100}");
                }
                last = snap.count;
            }
        });
    });
    let snap = hist.snapshot();
    assert_eq!(snap.count, THREADS * RECORDS, "no lost records");
    // all samples lie in (0, 64e-6]: the max estimate sits within one
    // ×2 bucket-growth factor of the true max
    let p100 = snap.quantile(1.0);
    assert!((32e-6..=128e-6).contains(&p100), "p100 {p100} off by over a growth factor");
}

#[test]
fn cursor_queue_is_exactly_once_under_racing_claims() {
    const ITEMS: u32 = 10_000;
    const WORKERS: usize = 8;
    let queue = Arc::new(CursorQueue::new((0..ITEMS).collect()));
    let mut claimed: Vec<u32> = thread::scope(|scope| {
        let handles: Vec<_> = (0..WORKERS)
            .map(|_| {
                let queue = Arc::clone(&queue);
                scope.spawn(move || {
                    let mut mine = Vec::new();
                    while let Some(item) = queue.claim() {
                        mine.push(item);
                    }
                    mine
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    claimed.sort_unstable();
    assert_eq!(claimed, (0..ITEMS).collect::<Vec<_>>(), "exactly-once claim set");
}

#[test]
fn steal_deques_are_exactly_once_under_racing_claims() {
    const PER_WORKER: u32 = 1000;
    const WORKERS: usize = 4;
    for steal_half in [false, true] {
        let seeds: Vec<Vec<u32>> = (0..WORKERS as u32)
            .map(|w| (w * PER_WORKER..(w + 1) * PER_WORKER).collect())
            .collect();
        let deques = Arc::new(StealDeques::new(seeds, steal_half));
        let mut claimed: Vec<u32> = thread::scope(|scope| {
            let handles: Vec<_> = (0..WORKERS)
                .map(|w| {
                    let deques = Arc::clone(&deques);
                    scope.spawn(move || {
                        let mut mine = Vec::new();
                        while let Some(c) = deques.claim(w) {
                            mine.push(c.item);
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        claimed.sort_unstable();
        assert_eq!(
            claimed,
            (0..WORKERS as u32 * PER_WORKER).collect::<Vec<_>>(),
            "exactly-once claim set (steal_half={steal_half})"
        );
    }
}
