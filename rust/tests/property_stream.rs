//! Property tests for the stream subsystem: random insert/delete batches
//! replayed on G(n,p) graphs, asserting after EVERY batch that the
//! incrementally maintained counts equal a from-scratch `Session::load` +
//! count of the mutated graph — with `baselines::slow` as a second oracle
//! on tiny graphs. Batches deliberately include self-loops, duplicate
//! inserts, deletes of nonexistent edges and out-of-range vertex ids.

use std::collections::HashSet;

use vdmc::baselines;
use vdmc::engine::{CountQuery, Session, SessionConfig};
use vdmc::graph::csr::Graph;
use vdmc::graph::generators;
use vdmc::motifs::{Direction, MotifSize};
use vdmc::stream::{DeltaOp, EdgeDelta};
use vdmc::util::rng::Pcg32;

/// Mirror of `apply_edges` semantics over a plain edge set (original ids).
fn apply_reference(
    reference: &mut HashSet<(u32, u32)>,
    n: u32,
    directed: bool,
    d: &EdgeDelta,
) {
    if d.u == d.v || d.u >= n || d.v >= n {
        return;
    }
    let key = if directed || d.u < d.v { (d.u, d.v) } else { (d.v, d.u) };
    match d.op {
        DeltaOp::Insert => {
            reference.insert(key);
        }
        DeltaOp::Delete => {
            reference.remove(&key);
        }
    }
}

fn reference_graph(reference: &HashSet<(u32, u32)>, n: usize, directed: bool) -> Graph {
    let edges: Vec<(u32, u32)> = reference.iter().copied().collect();
    Graph::from_edges(n, &edges, directed)
}

/// One adversarial batch: mostly random ops, plus guaranteed self-loops,
/// out-of-range ids, duplicate inserts and missing deletes.
fn adversarial_batch(
    rng: &mut Pcg32,
    n: u32,
    reference: &HashSet<(u32, u32)>,
) -> Vec<EdgeDelta> {
    let mut batch = Vec::new();
    for _ in 0..12 {
        let (u, v) = (rng.below(n), rng.below(n));
        if rng.bernoulli(0.55) {
            batch.push(EdgeDelta::insert(u, v));
        } else {
            batch.push(EdgeDelta::delete(u, v));
        }
    }
    batch.push(EdgeDelta::insert(3, 3)); // self loop
    batch.push(EdgeDelta::delete(0, 0)); // self loop
    batch.push(EdgeDelta::insert(n + 5, 1)); // out of range
    batch.push(EdgeDelta::delete(1, n + 9)); // out of range
    if let Some(&(u, v)) = reference.iter().next() {
        batch.push(EdgeDelta::insert(u, v)); // duplicate insert
    }
    batch.push(EdgeDelta::delete(n - 1, n - 2)); // likely-missing delete
    batch
}

fn check_replay(directed: bool, seed: u64, compact_ratio: f64) {
    let n = 24usize;
    let g = if directed {
        generators::gnp_directed(n, 0.12, seed)
    } else {
        generators::gnp_undirected(n, 0.12, seed)
    };
    let mut reference: HashSet<(u32, u32)> = if directed {
        g.out.edges().collect()
    } else {
        g.und.edges().filter(|&(u, v)| u < v).collect()
    };

    let mut session = Session::load_with(
        &g,
        &SessionConfig { workers: 2, compact_ratio, ..Default::default() },
    );
    let mut pairs = vec![
        (MotifSize::Three, Direction::Undirected),
        (MotifSize::Four, Direction::Undirected),
    ];
    if directed {
        pairs.push((MotifSize::Three, Direction::Directed));
        pairs.push((MotifSize::Four, Direction::Directed));
    }
    for &(size, dir) in &pairs {
        session.maintain(size, dir).unwrap();
    }

    let mut rng = Pcg32::seeded(seed ^ 0xFEED);
    for round in 0..6 {
        let batch = adversarial_batch(&mut rng, n as u32, &reference);
        for d in &batch {
            // semantics check below compares against this reference replay
            apply_reference(&mut reference, n as u32, directed, d);
        }
        let report = session.apply_edges(&batch).unwrap();
        assert_eq!(
            report.applied() + report.skipped(),
            batch.len(),
            "every op must be applied or skipped (round {round})"
        );
        assert!(report.skipped_invalid >= 4, "the planted invalid ops must be skipped");

        let want_graph = reference_graph(&reference, n, directed);
        let fresh = Session::load(&want_graph);
        for &(size, dir) in &pairs {
            let got = session.maintained_counts(size, dir).unwrap();
            let want = fresh
                .count(&CountQuery { size, direction: dir, ..Default::default() })
                .unwrap();
            assert_eq!(
                got.per_vertex, want.per_vertex,
                "maintained != reload ({size:?} {dir:?}, directed={directed}, seed={seed}, \
                 compact_ratio={compact_ratio}, round={round})"
            );
            assert_eq!(got.total_instances, want.total_instances);

            // second oracle: the deliberately-slow python-parity baseline
            let slow = baselines::slow::count(&want_graph, size, dir);
            assert_eq!(got.per_vertex, slow.per_vertex, "slow oracle ({size:?} {dir:?})");
        }
        // snapshot must equal the reference graph too
        let snap = session.snapshot_graph();
        assert_eq!(snap.und, want_graph.und, "snapshot und mismatch (round {round})");
        assert_eq!(snap.out, want_graph.out, "snapshot out mismatch (round {round})");
    }
}

#[test]
fn random_batches_match_reload_directed() {
    check_replay(true, 11, 0.25);
}

#[test]
fn random_batches_match_reload_undirected() {
    check_replay(false, 7, 0.25);
}

#[test]
fn always_compacting_matches_reload() {
    // ratio 0.0: every dirty batch rebuilds the CSR + partitions
    check_replay(true, 29, 0.0);
}

#[test]
fn never_compacting_matches_reload() {
    // the overlay absorbs every delta; counts must still be exact
    check_replay(false, 31, f64::INFINITY);
}

#[test]
fn direction_flips_on_reciprocal_edges() {
    // dense digraph so inserts frequently create reciprocal pairs and
    // deletes frequently leave one direction behind (und row survives)
    let n = 16usize;
    let g = generators::gnp_directed(n, 0.3, 5);
    let mut reference: HashSet<(u32, u32)> = g.out.edges().collect();
    let mut session =
        Session::load_with(&g, &SessionConfig { workers: 1, ..Default::default() });
    session.maintain(MotifSize::Three, Direction::Directed).unwrap();
    session.maintain(MotifSize::Four, Direction::Directed).unwrap();

    let mut rng = Pcg32::seeded(99);
    for _ in 0..5 {
        // bias toward reversing existing edges
        let mut batch = Vec::new();
        let existing: Vec<(u32, u32)> = reference.iter().copied().collect();
        for _ in 0..8 {
            let &(u, v) = &existing[rng.below_usize(existing.len())];
            if rng.bernoulli(0.5) {
                batch.push(EdgeDelta::insert(v, u)); // add the reverse
            } else {
                batch.push(EdgeDelta::delete(u, v)); // drop one direction
            }
        }
        for d in &batch {
            apply_reference(&mut reference, n as u32, true, d);
        }
        session.apply_edges(&batch).unwrap();
        let want_graph = reference_graph(&reference, n, true);
        let fresh = Session::load(&want_graph);
        for size in [MotifSize::Three, MotifSize::Four] {
            let got = session.maintained_counts(size, Direction::Directed).unwrap();
            let want = fresh
                .count(&CountQuery { size, direction: Direction::Directed, ..Default::default() })
                .unwrap();
            assert_eq!(got.per_vertex, want.per_vertex, "k={}", size.k());
        }
    }
}

#[test]
fn delta_locality_stays_sublinear() {
    // a sparse graph at test scale: a 100-op batch must re-enumerate far
    // fewer units than the whole graph holds (the bench pins the 5% bound
    // at the 50k-edge acceptance scale)
    let n = 2000usize;
    let g = generators::gnp_directed(n, 2.0e-3, 17);
    let mut session = Session::load_with(&g, &SessionConfig { workers: 2, ..Default::default() });
    session.maintain(MotifSize::Three, Direction::Directed).unwrap();
    let full_units = session.partitions().total_units;
    let mut rng = Pcg32::seeded(3);
    let batch: Vec<EdgeDelta> = (0..100)
        .map(|_| {
            let (u, v) = (rng.below(n as u32), rng.below(n as u32));
            if rng.bernoulli(0.5) {
                EdgeDelta::insert(u, v)
            } else {
                EdgeDelta::delete(u, v)
            }
        })
        .collect();
    let report = session.apply_edges(&batch).unwrap();
    assert!(report.applied() > 0);
    let frac = report.reenumerated_units as f64 / full_units.max(1) as f64;
    assert!(frac < 0.25, "100-op batch re-enumerated {:.1}% of a {}-unit graph", frac * 100.0, full_units);
}
