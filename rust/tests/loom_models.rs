//! Loom models for the lock-free core (build with `RUSTFLAGS="--cfg loom"`).
//!
//! Every structure under test takes its locks and atomics from the
//! `vdmc::sync` shim, which resolves to loom's instrumented primitives
//! here, so `loom::model` explores every interleaving the memory model
//! permits (preemption-bounded by `LOOM_MAX_PREEMPTIONS` in CI; the
//! offline vendored stand-in degrades to bounded stress — see
//! `rust/vendor/loom`).
//!
//! Invariants pinned, one model per claim:
//! - **epoch monotonicity**: a reader of `SnapshotCell` never observes
//!   the head epoch going backwards, with racing readers and with two
//!   writers serialized on the (production) writer mutex;
//! - **pin/retain accounting**: a pinned snapshot keeps its epoch alive
//!   and metered until the pin drops, then accounting returns to zero;
//! - **no lost cancels**: racing `CancelToken::cancel` calls elect
//!   exactly one winning reason, and a child spawned concurrently with
//!   a parent cancel observes the cancel once the cancelling thread is
//!   done — never a stuck-live token;
//! - **permit balance**: admission slots are released exactly once
//!   under every interleaving of enter/drop;
//! - **quantile consistency**: a histogram snapshot taken mid-record
//!   is internally consistent (count matches its own bucket reads) and
//!   final quantiles land within one growth factor of the recorded
//!   values;
//! - **exactly-once claims**: the scheduler's fetch-add cursor and the
//!   work-stealing deques hand every item to exactly one worker.
//!
//! Models keep ≤ 2 spawned threads (+ the model's main thread): loom's
//! default thread budget is small and state space is exponential in
//! threads × atomic ops.
#![cfg(loom)]

use loom::thread;
use std::sync::Arc;

use vdmc::engine::cancel::{AbortReason, CancelToken};
use vdmc::engine::deque::{CursorQueue, StealDeques};
use vdmc::engine::snapshot::{Snapshot, SnapshotCell};
use vdmc::service::admission::AdmissionGate;
use vdmc::sync::Mutex;
use vdmc::telemetry::metrics::MetricsRegistry;

/// Minimal `Snapshot` implementation: an epoch stamp plus a fixed byte
/// size, with `retained_vs` = full size unless the head *is* this
/// snapshot (mirrors how a superseded `SessionSnapshot` retains its
/// overlay while sharing the CSR).
struct TestSnap {
    epoch: u64,
    bytes: usize,
}

impl TestSnap {
    fn new(epoch: u64) -> Arc<TestSnap> {
        Arc::new(TestSnap { epoch, bytes: 100 })
    }
}

impl Snapshot for TestSnap {
    fn epoch(&self) -> u64 {
        self.epoch
    }
    fn memory_bytes(&self) -> usize {
        self.bytes
    }
    fn retained_vs(&self, head: &TestSnap) -> usize {
        if self.epoch == head.epoch {
            0
        } else {
            self.bytes
        }
    }
}

#[test]
fn snapshot_head_epochs_are_monotone_under_a_committing_writer() {
    loom::model(|| {
        let cell = Arc::new(SnapshotCell::new(TestSnap::new(0)));
        let writer = {
            let cell = Arc::clone(&cell);
            thread::spawn(move || {
                cell.commit(TestSnap::new(1));
                cell.commit(TestSnap::new(2));
            })
        };
        // Reader interleaves with the two commits: successive head()
        // calls must never observe the epoch going backwards.
        let e1 = cell.head().epoch();
        let e2 = cell.head().epoch();
        assert!(e1 <= e2, "epoch went backwards: {e1} -> {e2}");
        assert!(e2 <= 2, "epoch from the future: {e2}");
        writer.join().unwrap();
        assert_eq!(cell.epoch(), 2, "both commits must be visible after join");
    });
}

#[test]
fn snapshot_two_writers_serialized_on_the_writer_mutex_stay_monotone() {
    loom::model(|| {
        let cell = Arc::new(SnapshotCell::new(TestSnap::new(0)));
        // Production serializes commits on the pool's per-graph writer
        // mutex; the cell itself only promises head-swap atomicity.
        // Model exactly that protocol with two racing writers.
        let writer_mutex = Arc::new(Mutex::new(()));
        let spawn_writer = |cell: &Arc<SnapshotCell<TestSnap>>,
                            writer_mutex: &Arc<Mutex<()>>| {
            let cell = Arc::clone(cell);
            let writer_mutex = Arc::clone(writer_mutex);
            thread::spawn(move || {
                let guard = writer_mutex.lock().unwrap();
                let next = cell.epoch() + 1;
                cell.commit(TestSnap::new(next));
                drop(guard);
            })
        };
        let w1 = spawn_writer(&cell, &writer_mutex);
        let w2 = spawn_writer(&cell, &writer_mutex);
        let e1 = cell.head().epoch();
        let e2 = cell.head().epoch();
        assert!(e1 <= e2, "reader saw epochs regress: {e1} -> {e2}");
        w1.join().unwrap();
        w2.join().unwrap();
        assert_eq!(cell.epoch(), 2, "serialized writers must stack epochs");
    });
}

#[test]
fn snapshot_pin_keeps_its_epoch_alive_until_dropped() {
    loom::model(|| {
        let cell = Arc::new(SnapshotCell::new(TestSnap::new(0)));
        let reader = {
            let cell = Arc::clone(&cell);
            // The pin races the commit: it lands on epoch 0 or 1.
            thread::spawn(move || cell.head())
        };
        cell.commit(TestSnap::new(1));
        let pin = reader.join().unwrap();
        assert_eq!(cell.epoch(), 1);
        // Exactly one snapshot is pinned outside the cell, whichever
        // epoch the reader caught; a superseded pin also retains bytes.
        assert_eq!(cell.pinned_snapshots(), 1);
        if pin.epoch() == 0 {
            assert_eq!(cell.retained_bytes(), 100, "superseded pin must be metered");
            assert_eq!(cell.resident_bytes(), 200);
        } else {
            assert_eq!(cell.retained_bytes(), 0, "a head pin retains nothing extra");
            assert_eq!(cell.resident_bytes(), 100);
        }
        drop(pin);
        assert_eq!(cell.pinned_snapshots(), 0, "accounting must return to zero");
        assert_eq!(cell.retained_bytes(), 0);
    });
}

#[test]
fn cancel_racing_cancels_elect_exactly_one_reason() {
    loom::model(|| {
        let token = CancelToken::new();
        let t1 = {
            let token = token.clone();
            thread::spawn(move || token.cancel(AbortReason::Deadline))
        };
        let t2 = {
            let token = token.clone();
            thread::spawn(move || token.cancel(AbortReason::Shutdown))
        };
        let won1 = t1.join().unwrap();
        let won2 = t2.join().unwrap();
        assert!(won1 ^ won2, "exactly one cancel must win (got {won1}, {won2})");
        let reason = token.check().expect("token must be cancelled after both joins");
        let winner = if won1 { AbortReason::Deadline } else { AbortReason::Shutdown };
        assert_eq!(reason, winner, "the observed reason must be the winner's");
    });
}

#[test]
fn cancel_vs_spawn_child_never_loses_the_cancel() {
    loom::model(|| {
        let conn = CancelToken::new();
        let canceller = {
            let conn = conn.clone();
            thread::spawn(move || {
                conn.cancel(AbortReason::ClientGone);
            })
        };
        // The child is derived concurrently with the parent cancel —
        // the serve loop's cancel-vs-spawn race. Mid-race it may still
        // read live, but only with the parent's reason once cancelled.
        let child = conn.child(None, None);
        match child.check() {
            None | Some(AbortReason::ClientGone) => {}
            other => panic!("child saw an impossible reason: {other:?}"),
        }
        canceller.join().unwrap();
        assert_eq!(
            child.check(),
            Some(AbortReason::ClientGone),
            "a child spawned during the cancel must observe it after the cancel completes"
        );
        // A child derived after the cancel is born cancelled.
        assert_eq!(conn.child(None, None).check(), Some(AbortReason::ClientGone));
    });
}

#[test]
fn admission_permits_balance_under_every_interleaving() {
    loom::model(|| {
        let gate = Arc::new(AdmissionGate::new());
        let spawn_request = |gate: &Arc<AdmissionGate>| {
            let gate = Arc::clone(gate);
            thread::spawn(move || {
                let (inflight, permit) = gate.enter();
                assert!(
                    (1..=2).contains(&inflight),
                    "inflight out of range with 2 requests: {inflight}"
                );
                drop(permit);
                inflight
            })
        };
        let t1 = spawn_request(&gate);
        let t2 = spawn_request(&gate);
        let (i1, i2) = (t1.join().unwrap(), t2.join().unwrap());
        // The two RMWs are totally ordered: both threads can see 1
        // (enter/drop/enter) but never both see 2.
        assert!(!(i1 == 2 && i2 == 2), "both requests counted each other twice");
        assert_eq!(gate.inflight(), 0, "all permits returned, balance must be zero");
    });
}

#[test]
fn histogram_snapshot_is_consistent_mid_record() {
    loom::model(|| {
        let registry = MetricsRegistry::new();
        let hist = registry.histogram("loom_model_seconds", "loom model test histogram");
        let writer = {
            let hist = Arc::clone(&hist);
            thread::spawn(move || {
                hist.record(1e-6);
                hist.record(1.0);
            })
        };
        // A snapshot taken mid-record rebuilds its count from its own
        // bucket reads, so quantile math can't tear: any count in
        // 0..=2 is valid, and the quantile is defined whenever > 0.
        let snap = hist.snapshot();
        assert!(snap.count <= 2, "snapshot invented samples: {}", snap.count);
        if snap.count > 0 {
            let q = snap.quantile(1.0);
            assert!(q.is_finite() && q >= 0.0, "invalid quantile {q}");
        }
        writer.join().unwrap();
        let snap = hist.snapshot();
        assert_eq!(snap.count, 2, "both records must be visible after join");
        // Bucket bounds grow ×2 from 1e-6: the estimates land within
        // one growth factor of the true values.
        assert!(snap.quantile(0.25) <= 1e-6 * 1.0001, "p25 must sit in the first bucket");
        let p100 = snap.quantile(1.0);
        assert!((0.5..=2.0 + 1e-9).contains(&p100), "p100 {p100} not within a factor of 1.0");
    });
}

#[test]
fn cursor_queue_hands_each_item_to_exactly_one_worker() {
    loom::model(|| {
        let queue = Arc::new(CursorQueue::new(vec![10u32, 20, 30]));
        let spawn_worker = |queue: &Arc<CursorQueue<u32>>| {
            let queue = Arc::clone(queue);
            thread::spawn(move || {
                let mut mine = Vec::new();
                while let Some(item) = queue.claim() {
                    mine.push(item);
                }
                mine
            })
        };
        let t1 = spawn_worker(&queue);
        let t2 = spawn_worker(&queue);
        let mut claimed = t1.join().unwrap();
        claimed.extend(t2.join().unwrap());
        claimed.sort_unstable();
        assert_eq!(claimed, vec![10, 20, 30], "each item claimed exactly once");
        assert!(queue.claim().is_none(), "drained queue stays drained");
    });
}

#[test]
fn steal_deques_claim_each_item_exactly_once() {
    loom::model(|| {
        // Worker 1 starts empty so every interleaving forces a steal
        // (single-item mode; half-deque batches share the same locking
        // and are raced in tests/concurrency_stress.rs).
        let deques = Arc::new(StealDeques::new(vec![vec![1u32, 2, 3], Vec::new()], false));
        let thief = {
            let deques = Arc::clone(&deques);
            thread::spawn(move || {
                let mut mine = Vec::new();
                while let Some(claimed) = deques.claim(1) {
                    assert!(claimed.stolen, "worker 1 has no local items");
                    mine.push(claimed.item);
                }
                mine
            })
        };
        let mut claimed = Vec::new();
        while let Some(c) = deques.claim(0) {
            claimed.push(c.item);
        }
        claimed.extend(thief.join().unwrap());
        claimed.sort_unstable();
        assert_eq!(claimed, vec![1, 2, 3], "each item claimed exactly once across steals");
    });
}
