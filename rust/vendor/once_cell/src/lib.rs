//! Minimal offline stand-in for the `once_cell` crate: `sync::Lazy`
//! implemented over `std::sync::OnceLock` (the std feature that obsoleted
//! it). Only the surface VDMC uses.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, usable in `static`s.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static COUNTER: Lazy<u32> = Lazy::new(|| 40 + 2);

    #[test]
    fn static_lazy_initializes_once() {
        assert_eq!(*COUNTER, 42);
        assert_eq!(*COUNTER, 42);
    }

    #[test]
    fn local_lazy() {
        let calls = std::sync::atomic::AtomicU32::new(0);
        let l = Lazy::new(|| {
            calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            7u32
        });
        assert_eq!(*l, 7);
        assert_eq!(*l, 7);
        assert_eq!(calls.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
