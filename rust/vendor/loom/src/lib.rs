//! Offline stand-in for the [loom](https://docs.rs/loom) model checker.
//!
//! The vendored offline registry ships no `loom`, so this crate mirrors
//! the subset of loom 0.7's surface that `vdmc::sync` and
//! `tests/loom_models.rs` use, backed by plain `std` primitives.
//! Semantics degrade from *exhaustive interleaving exploration* to
//! *bounded stress*: [`model`] re-runs the closure `LOOM_ITERS` times
//! (default 64) on real OS threads instead of enumerating schedules.
//!
//! The CI `loom-models` job swaps this path dependency for the real
//! `loom = "0.7"` crate (network is available there) and runs the same
//! test binary exhaustively; this stand-in keeps `--cfg loom` builds
//! compiling offline and makes a local `cargo test --test loom_models`
//! a meaningful smoke run. Only the common API subset is exposed, so
//! code that compiles against the stand-in compiles against real loom.

/// Run `f` under the model. Real loom explores every interleaving
/// permitted by the memory model (bounded by `LOOM_MAX_PREEMPTIONS`);
/// this stand-in re-runs it `LOOM_ITERS` times (default 64) as a
/// bounded stress fallback.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    let iters: usize = std::env::var("LOOM_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    for _ in 0..iters {
        f();
    }
}

/// Mirror of `loom::thread`.
pub mod thread {
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

/// Mirror of `loom::hint`.
pub mod hint {
    pub use std::hint::spin_loop;
}

/// Mirror of `loom::sync`: locks, guards and atomics.
pub mod sync {
    pub use std::sync::{
        Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    };

    /// Mirror of `loom::sync::atomic`.
    pub mod atomic {
        pub use std::sync::atomic::{
            fence, AtomicBool, AtomicI16, AtomicI32, AtomicI64, AtomicI8, AtomicIsize, AtomicU16,
            AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
        };
    }
}
