//! Compile-time stub of the `xla` crate (PJRT bindings).
//!
//! The offline build environment has neither the `xla` crate nor an
//! `xla_extension` shared library, so this stub mirrors the exact API
//! surface `vdmc::runtime` uses and fails cleanly at *runtime* when a
//! PJRT client is requested. All artifact-gated tests and examples probe
//! for `artifacts/manifest.tsv` first and skip, so the stub never executes
//! in CI; on a machine with the real `xla` crate, drop it into the
//! workspace `[patch]` table and everything downstream works unchanged.

use std::fmt;
use std::path::Path;

/// Stub error: carries a human-readable message.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (vdmc was built against the offline xla stub; \
         install the real `xla` crate + xla_extension to execute artifacts)"
    ))
}

/// Element types of the artifacts VDMC ships (f32 and s32 only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Marker for element types [`Literal::to_vec`] can extract.
pub trait NativeType: Sized + Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side tensor value (stub: never holds data).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    /// Unwrap a 1-tuple literal (aot.py lowers with `return_tuple=True`).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing {}", path.as_ref().display())))
    }
}

/// An XLA computation ready to compile (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// `L` mirrors the real crate's generic over input buffer kinds.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literal_paths_fail_cleanly() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8]).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
