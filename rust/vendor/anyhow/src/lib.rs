//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network crate registry, so the subset of
//! `anyhow` that VDMC uses is implemented here: an [`Error`] type carrying
//! a context chain, the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Semantics mirror the
//! real crate: `Display` prints the outermost context, `{:#}` prints the
//! whole chain separated by `: `.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root cause plus a stack of human context messages.
pub struct Error {
    /// Context chain, outermost message first; the last entry is the root.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(...)` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Context messages from outermost to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// Like the real anyhow: Error deliberately does NOT implement
// std::error::Error, which is what makes this blanket From coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Extension trait attaching context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*).into());
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::Error::msg(concat!("condition failed: ", stringify!($cond))).into(),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "opening x".to_string()).unwrap_err();
        assert!(format!("{e:#}").contains("opening x"));
        let o: Option<u32> = None;
        let e = o.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
